//! Cohort formation: partition each cell's users into fixed-size solver
//! cohorts and pick candidate subchannels per cohort.
//!
//! Cohorts are the static-shape unit of both the analytic Li-GD solver and
//! the AOT-compiled XLA solver, so their size is a config constant. Channel
//! candidates are chosen least-loaded-first so sequentially planned cohorts
//! spread across the spectrum (the NOMA cluster cap is enforced when
//! rounding).

use crate::config::Config;
use crate::net::Network;

/// One cohort: users (same cell) + candidate global channel indices.
#[derive(Clone, Debug)]
pub struct Cohort {
    pub ap: usize,
    pub users: Vec<usize>,
    pub channels: Vec<usize>,
}

/// Tracks per-(ap, channel) NOMA cluster occupancy while planning.
#[derive(Clone, Debug)]
pub struct ChannelLoad {
    pub counts: Vec<Vec<usize>>,
    pub cap: usize,
}

impl ChannelLoad {
    pub fn new(n_aps: usize, n_channels: usize, cap: usize) -> Self {
        Self {
            counts: vec![vec![0; n_channels]; n_aps],
            cap,
        }
    }

    /// `k` least-loaded channels of cell `ap` that still have capacity;
    /// pads with globally least-loaded if fewer have room.
    pub fn candidates(&self, ap: usize, k: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.counts[ap].len()).collect();
        order.sort_by_key(|&c| self.counts[ap][c]);
        // Capacity first: channels with room (least-loaded order), then —
        // only if fewer than `k` have room — pad with the least-loaded
        // saturated ones. The stable sort keeps both halves least-loaded
        // ordered, so the padding really is "globally least-loaded".
        let (roomy, full): (Vec<usize>, Vec<usize>) =
            order.into_iter().partition(|&c| self.has_room(ap, c));
        roomy.into_iter().chain(full).take(k).collect()
    }

    /// Gain-aware candidates: within the least-loaded tier, prefer the
    /// channels where the cohort's users actually have good fading draws
    /// (score = Σ_user gain / (1 + load)). This is what lets the NOMA
    /// planner exploit multi-user channel diversity instead of handing it
    /// to the matching-based baselines. Same capacity contract as
    /// [`ChannelLoad::candidates`]: channels with room lead (best score
    /// first); cap-saturated ones only pad when fewer than `k` have room —
    /// handing the solver a channel it cannot commit wastes its power
    /// budget on a guaranteed rounding fallback.
    pub fn candidates_for(
        &self,
        ap: usize,
        k: usize,
        cohort_users: &[usize],
        up_gains: &[Vec<Vec<f64>>],
    ) -> Vec<usize> {
        let n = self.counts[ap].len();
        let mut scored: Vec<(usize, f64)> = (0..n)
            .map(|c| {
                let g: f64 = cohort_users.iter().map(|&u| up_gains[u][ap][c]).sum();
                (c, g / (1.0 + self.counts[ap][c] as f64))
            })
            .collect();
        // `total_cmp`: a NaN gain draw must not panic the planner hot path
        // (NaN scores sort deterministically instead).
        scored.sort_by(|a, b| b.1.total_cmp(&a.1));
        let (roomy, full): (Vec<(usize, f64)>, Vec<(usize, f64)>) = scored
            .into_iter()
            .partition(|&(c, _)| self.has_room(ap, c));
        roomy
            .into_iter()
            .chain(full)
            .take(k)
            .map(|(c, _)| c)
            .collect()
    }

    pub fn commit(&mut self, ap: usize, ch: usize) {
        self.counts[ap][ch] += 1;
    }

    pub fn has_room(&self, ap: usize, ch: usize) -> bool {
        self.counts[ap][ch] < self.cap
    }

    /// Least-loaded channel with room, if any.
    pub fn fallback(&self, ap: usize) -> Option<usize> {
        (0..self.counts[ap].len())
            .filter(|&c| self.has_room(ap, c))
            .min_by_key(|&c| self.counts[ap][c])
    }

    /// Best channel with room for a specific user: maximize the user's
    /// uplink gain among the least-loaded tier (gain-aware fallback —
    /// fading is per-channel, so a blind least-loaded pick can cost 10 dB).
    pub fn best_fallback(&self, ap: usize, gains: &[f64]) -> Option<usize> {
        let min_load = (0..self.counts[ap].len())
            .filter(|&c| self.has_room(ap, c))
            .map(|c| self.counts[ap][c])
            .min()?;
        (0..self.counts[ap].len())
            .filter(|&c| self.has_room(ap, c) && self.counts[ap][c] <= min_load + 1)
            .max_by(|&a, &b| gains[a].total_cmp(&gains[b]))
    }
}

/// Partition all users into cohorts (per cell, chunks of
/// `cfg.optimizer.cohort_users`), with gain-aware channel candidates.
pub fn form_cohorts(cfg: &Config, net: &Network, load: &ChannelLoad) -> Vec<Cohort> {
    form_cohorts_masked(cfg, net, load, None)
}

/// [`form_cohorts`] restricted to an active-user mask (`None` = everyone).
/// The dynamic serving engine re-plans each epoch on the currently-active
/// population only — departed users must not occupy cohort slots or bias
/// the gain-aware channel choice.
pub fn form_cohorts_masked(
    cfg: &Config,
    net: &Network,
    load: &ChannelLoad,
    active: Option<&[bool]>,
) -> Vec<Cohort> {
    let mut cohorts = Vec::new();
    for ap in 0..cfg.network.num_aps {
        let members: Vec<usize> = net
            .topo
            .users_of_ap(ap)
            .into_iter()
            .filter(|&u| active.map_or(true, |m| m[u]))
            .collect();
        for chunk in members.chunks(cfg.optimizer.cohort_users) {
            cohorts.push(Cohort {
                ap,
                users: chunk.to_vec(),
                channels: load.candidates_for(
                    ap,
                    cfg.optimizer.cohort_channels,
                    chunk,
                    &net.channels.up,
                ),
            });
        }
    }
    cohorts
}

/// Persistent user → cohort-slot assignment per AP — the churn-stable
/// alternative to chunk-based formation (DESIGN.md §2e).
///
/// Each AP owns a slot vector; slot `i` belongs to cohort group `i /
/// cohort_users`. A departing (or handed-off) user leaves a hole at its
/// slot; the next activation fills the lowest hole before new slots are
/// appended. Slot indices therefore never shift, so one churn event
/// perturbs exactly the cohort group(s) it touches — a departure dirties
/// one cohort, a handoff at most two — instead of re-chunking every
/// downstream cohort of the AP the way `form_cohorts_masked` does.
///
/// The table is cross-epoch state: it lives in
/// [`crate::coordinator::PlanCache`] and is only consulted by the
/// incremental planner when `optimizer.stable_cohorts` is set.
#[derive(Clone, Debug, Default)]
pub struct SlotTable {
    /// `slots[ap][i]` = user occupying slot `i` of AP `ap` (`None` = hole).
    slots: Vec<Vec<Option<usize>>>,
    /// Inverse map: `slot_of[user]` = `(ap, slot index)` when assigned.
    slot_of: Vec<Option<(usize, usize)>>,
}

impl SlotTable {
    /// Bring the table in sync with the current association + activity
    /// mask: evict departed/moved users (leaving holes), then admit new
    /// active users in ascending id order — each fills the lowest hole of
    /// its AP, else appends. Trailing holes are truncated (kept indices
    /// never shift). Deterministic in `(net, active)`.
    fn sync(&mut self, cfg: &Config, net: &Network, active: Option<&[bool]>) {
        let n_aps = cfg.network.num_aps;
        let nu = net.num_users();
        if self.slot_of.len() < nu && self.slots.len() == n_aps {
            // Population grew in place (shard-local nets append members as
            // users arrive): extend without disturbing existing slots —
            // cohort identity must survive admissions.
            self.slot_of.resize(nu, None);
        }
        if self.slots.len() != n_aps || self.slot_of.len() != nu {
            // population shape changed (new episode / new network): reset
            self.slots = vec![Vec::new(); n_aps];
            self.slot_of = vec![None; nu];
        }
        let is_active = |u: usize| active.map_or(true, |m| m[u]);
        for u in 0..nu {
            if let Some((ap, idx)) = self.slot_of[u] {
                if !is_active(u) || net.topo.user_ap[u] != ap {
                    self.slots[ap][idx] = None;
                    self.slot_of[u] = None;
                }
            }
        }
        for u in 0..nu {
            if self.slot_of[u].is_none() && is_active(u) {
                let ap = net.topo.user_ap[u];
                let row = &mut self.slots[ap];
                let idx = match row.iter().position(|s| s.is_none()) {
                    Some(hole) => hole,
                    None => {
                        row.push(None);
                        row.len() - 1
                    }
                };
                row[idx] = Some(u);
                self.slot_of[u] = Some((ap, idx));
            }
        }
        if cfg.optimizer.slot_compact_frac > 0.0 {
            for ap in 0..n_aps {
                self.compact_ap(
                    ap,
                    cfg.optimizer.cohort_users,
                    cfg.optimizer.slot_compact_frac,
                );
            }
        }
        for row in &mut self.slots {
            while matches!(row.last(), Some(None)) {
                row.pop();
            }
        }
    }

    /// Hysteresis compaction (DESIGN.md §2f): sustained departure skew can
    /// strand many near-empty slot groups, and since groups never merge on
    /// their own the cohort count drifts arbitrarily far above
    /// ⌈active / k⌉. Merge each group at or below `⌊k · frac⌋` occupancy
    /// into its nearest non-empty neighbor group (previous first, then
    /// next) when the union fits in one group. Members move in ascending
    /// slot order into the target's lowest holes, so the result is
    /// deterministic. Each merge dirties exactly the two involved groups —
    /// a one-epoch re-solve hit that bounds the drift: afterwards every
    /// surviving ≤`⌊k·frac⌋` group is wedged between groups too full to
    /// absorb it (> `k − ⌊k·frac⌋` occupancy), so with the default ¼
    /// fraction the steady-state group count stays within ~8/3 of ideal.
    fn compact_ap(&mut self, ap: usize, k: usize, frac: f64) {
        let thresh = ((k as f64) * frac).floor() as usize;
        if thresh == 0 || k == 0 {
            return;
        }
        let row_len = self.slots[ap].len();
        let n_groups = row_len.div_ceil(k);
        let mut occ = vec![0usize; n_groups];
        for (idx, s) in self.slots[ap].iter().enumerate() {
            if s.is_some() {
                occ[idx / k] += 1;
            }
        }
        let groups: Vec<usize> = (0..n_groups).filter(|&g| occ[g] > 0).collect();
        let mut prev: Option<usize> = None;
        for (j, &g) in groups.iter().enumerate() {
            if occ[g] > thresh {
                prev = Some(g);
                continue;
            }
            let cand_prev = prev.filter(|&p| occ[p] + occ[g] <= k);
            let cand_next = groups
                .get(j + 1)
                .copied()
                .filter(|&n| occ[n] + occ[g] <= k);
            let Some(t) = cand_prev.or(cand_next) else {
                // no neighbor can absorb this group: it survives (the
                // hysteresis guarantee — both neighbors are > k - thresh)
                prev = Some(g);
                continue;
            };
            // Move g's members (ascending slot order) into t's lowest
            // holes; extend the row when t is a partial trailing group.
            let movers: Vec<usize> = (g * k..(g + 1) * k)
                .filter(|&i| i < self.slots[ap].len())
                .filter_map(|i| self.slots[ap][i].take())
                .collect();
            let t_end = ((t + 1) * k).min(self.slots[ap].len());
            let mut holes: Vec<usize> = (t * k..t_end)
                .filter(|&i| self.slots[ap][i].is_none())
                .collect();
            holes.reverse(); // pop() yields the lowest hole first
            for u in movers {
                let idx = match holes.pop() {
                    Some(h) => h,
                    None => {
                        debug_assert!(self.slots[ap].len() < (t + 1) * k);
                        self.slots[ap].push(None);
                        self.slots[ap].len() - 1
                    }
                };
                self.slots[ap][idx] = Some(u);
                self.slot_of[u] = Some((ap, idx));
            }
            occ[t] += occ[g];
            occ[g] = 0;
            // `prev` stays: g vanished, its predecessor is still the
            // nearest surviving group on the left.
        }
    }

    /// Number of slots currently tracked for `ap` (diagnostics/tests).
    pub fn slots_of_ap(&self, ap: usize) -> usize {
        self.slots.get(ap).map_or(0, |row| row.len())
    }
}

/// Churn-stable cohort formation: sync the persistent [`SlotTable`] with
/// the active set, then emit one cohort per non-empty slot group. Members
/// are listed in ascending user id (the canonical order — a cohort's
/// member *set* fully determines its solver inputs, which is what lets
/// the plan cache key solutions by member set). Returns each cohort with
/// its stable slot-group index.
///
/// For a fresh table with no churn history this produces exactly the same
/// cohorts as [`form_cohorts_masked`] (users admitted in ascending order
/// fill slots in order ⇒ the chunks), so churn-off behavior is identical.
pub fn form_cohorts_stable(
    cfg: &Config,
    net: &Network,
    load: &ChannelLoad,
    active: Option<&[bool]>,
    table: &mut SlotTable,
) -> Vec<(usize, Cohort)> {
    table.sync(cfg, net, active);
    let k = cfg.optimizer.cohort_users;
    let mut cohorts = Vec::new();
    for ap in 0..cfg.network.num_aps {
        for (group, slots) in table.slots[ap].chunks(k).enumerate() {
            let mut users: Vec<usize> = slots.iter().filter_map(|&s| s).collect();
            if users.is_empty() {
                continue;
            }
            users.sort_unstable();
            let channels = load.candidates_for(
                ap,
                cfg.optimizer.cohort_channels,
                &users,
                &net.channels.up,
            );
            cohorts.push((
                group,
                Cohort {
                    ap,
                    users,
                    channels,
                },
            ));
        }
    }
    cohorts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::net::Network;

    #[test]
    fn cohorts_cover_all_users_once() {
        let cfg = presets::smoke();
        let net = Network::generate(&cfg, 3);
        let load = ChannelLoad::new(cfg.network.num_aps, cfg.network.num_subchannels, 3);
        let cohorts = form_cohorts(&cfg, &net, &load);
        let mut seen = vec![false; net.num_users()];
        for c in &cohorts {
            assert!(c.users.len() <= cfg.optimizer.cohort_users);
            assert_eq!(c.channels.len(), cfg.optimizer.cohort_channels.min(cfg.network.num_subchannels));
            for &u in &c.users {
                assert!(!seen[u], "user {u} in two cohorts");
                seen[u] = true;
                assert_eq!(net.topo.user_ap[u], c.ap);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn masked_cohorts_cover_exactly_the_active_users() {
        let cfg = presets::smoke();
        let net = Network::generate(&cfg, 3);
        let load = ChannelLoad::new(cfg.network.num_aps, cfg.network.num_subchannels, 3);
        let active: Vec<bool> = (0..net.num_users()).map(|u| u % 3 != 0).collect();
        let cohorts = form_cohorts_masked(&cfg, &net, &load, Some(&active));
        let mut seen = vec![false; net.num_users()];
        for c in &cohorts {
            for &u in &c.users {
                assert!(active[u], "inactive user {u} planned into a cohort");
                assert!(!seen[u]);
                seen[u] = true;
            }
        }
        for (u, &a) in active.iter().enumerate() {
            assert_eq!(seen[u], a, "user {u}");
        }
    }

    #[test]
    fn load_tracking() {
        let mut load = ChannelLoad::new(1, 4, 2);
        assert!(load.has_room(0, 0));
        load.commit(0, 0);
        load.commit(0, 0);
        assert!(!load.has_room(0, 0));
        assert_eq!(load.fallback(0), Some(1));
        // candidates prefer empties
        let cand = load.candidates(0, 2);
        assert!(!cand.contains(&0));

        // The documented capacity contract: channels with room come first,
        // saturated ones only pad when fewer than `k` have room. Channel 0
        // is at cap (2) and channel 1 at 1 commit — with k = 4 every
        // channel is returned, but 0 must come *last* despite the sort
        // being purely load-ordered before the fix.
        load.commit(0, 1);
        let cand = load.candidates(0, 4);
        assert_eq!(cand.len(), 4);
        assert_eq!(cand[3], 0, "cap-saturated channel pads last: {cand:?}");
        assert_eq!(&cand[..2], &[2, 3], "empties lead");
        assert_eq!(cand[2], 1);
        // and with k small enough, a saturated channel is never returned
        for k in 1..=3 {
            assert!(
                !load.candidates(0, k).contains(&0),
                "k={k} returned a channel with no capacity"
            );
        }
    }

    #[test]
    fn gain_aware_candidates_respect_capacity_first() {
        // The live-path variant of the `candidates` contract: a channel at
        // the cluster cap is only returned when fewer than `k` channels
        // have room, however good its gain.
        let mut load = ChannelLoad::new(1, 3, 1);
        load.commit(0, 0); // channel 0 saturated
        let up_gains = vec![vec![vec![100.0, 1.0, 2.0]]]; // ch 0 gain dominates
        let cand = load.candidates_for(0, 2, &[0], &up_gains);
        assert_eq!(cand, vec![2, 1], "saturated best-gain channel excluded");
        let all = load.candidates_for(0, 3, &[0], &up_gains);
        assert_eq!(all, vec![2, 1, 0], "padded last when k exceeds the room");
    }

    #[test]
    fn nan_gain_draws_do_not_panic_candidate_selection() {
        // Regression: `candidates_for` / `best_fallback` used
        // `partial_cmp(..).unwrap()`, which panics the planner on a single
        // NaN gain. They must stay total and deterministic instead.
        let load = ChannelLoad::new(1, 3, 2);
        let up_gains = vec![vec![vec![f64::NAN, 1.0, 2.0]]]; // user 0, ap 0
        let cand = load.candidates_for(0, 2, &[0], &up_gains);
        assert_eq!(cand.len(), 2);
        let gains = [f64::NAN, 0.5, 0.25];
        let fb = load.best_fallback(0, &gains);
        assert!(fb.is_some(), "a NaN gain must not wipe out the fallback");
    }

    #[test]
    fn stable_formation_matches_chunks_without_churn() {
        let cfg = presets::smoke();
        let net = Network::generate(&cfg, 3);
        let load = ChannelLoad::new(cfg.network.num_aps, cfg.network.num_subchannels, 3);
        let active: Vec<bool> = (0..net.num_users()).map(|u| u % 3 != 0).collect();
        let chunked = form_cohorts_masked(&cfg, &net, &load, Some(&active));
        let mut table = SlotTable::default();
        let stable = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);
        assert_eq!(stable.len(), chunked.len());
        for ((group, s), c) in stable.iter().zip(chunked.iter()) {
            assert_eq!(s.ap, c.ap);
            assert_eq!(s.users, c.users, "fresh table == chunks");
            assert_eq!(s.channels, c.channels);
            let _ = group;
        }
        // re-forming with the same mask is a fixed point
        let again = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);
        for ((ga, a), (gb, b)) in stable.iter().zip(again.iter()) {
            assert_eq!(ga, gb);
            assert_eq!(a.users, b.users);
        }
    }

    #[test]
    fn departure_perturbs_one_cohort_and_the_hole_is_refilled() {
        let mut cfg = presets::smoke();
        cfg.network.num_users = 48; // several cohorts per AP
        let net = Network::generate(&cfg, 11);
        let load = ChannelLoad::new(cfg.network.num_aps, cfg.network.num_subchannels, 3);
        let mut active = vec![true; net.num_users()];
        let mut table = SlotTable::default();
        let before = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);

        // Depart the *first* member of AP 0 — the chunk formation's worst
        // case (it shifts every downstream chunk of that AP).
        let departed = *net.topo.users_of_ap(0).first().expect("AP 0 has users");
        active[departed] = false;
        let after = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);
        let changed: Vec<usize> = before
            .iter()
            .filter(|(g, c)| {
                !after
                    .iter()
                    .any(|(g2, c2)| *g2 == *g && c2.ap == c.ap && c2.users == c.users)
            })
            .map(|(g, _)| *g)
            .collect();
        assert_eq!(changed.len(), 1, "exactly one cohort changed: {changed:?}");

        // A re-arrival fills the hole: membership reverts exactly.
        active[departed] = true;
        let back = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);
        assert_eq!(back.len(), before.len());
        for ((ga, a), (gb, b)) in back.iter().zip(before.iter()) {
            assert_eq!(ga, gb);
            assert_eq!(a.users, b.users, "hole refilled by the returning user");
        }
    }

    #[test]
    fn handoff_perturbs_at_most_two_cohorts() {
        let mut cfg = presets::smoke();
        cfg.network.num_users = 48;
        let net = Network::generate(&cfg, 12);
        assert!(cfg.network.num_aps >= 2, "handoff needs two APs");
        let load = ChannelLoad::new(cfg.network.num_aps, cfg.network.num_subchannels, 3);
        let active = vec![true; net.num_users()];
        let mut table = SlotTable::default();
        let before = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);

        // Hand the first user of AP 0 off to AP 1 on a cloned network.
        let mover = *net.topo.users_of_ap(0).first().expect("AP 0 has users");
        let mut net2 = net.clone();
        net2.topo.user_ap[mover] = 1;
        let after = form_cohorts_stable(&cfg, &net2, &load, Some(&active), &mut table);
        let changed = before
            .iter()
            .filter(|(g, c)| {
                !after
                    .iter()
                    .any(|(g2, c2)| *g2 == *g && c2.ap == c.ap && c2.users == c.users)
            })
            .count();
        let appeared = after
            .iter()
            .filter(|(g, c)| {
                !before
                    .iter()
                    .any(|(g2, c2)| *g2 == *g && c2.ap == c.ap && c2.users == c.users)
            })
            .count();
        assert!(changed <= 2, "handoff changed {changed} source cohorts");
        assert!(appeared <= 2, "handoff produced {appeared} new cohorts");
        // the mover really lives in AP 1 now
        assert!(after
            .iter()
            .any(|(_, c)| c.ap == 1 && c.users.contains(&mover)));
    }

    #[test]
    fn compaction_merges_fragmented_groups_and_dirties_only_them() {
        // §2f hysteresis compaction: two sub-threshold groups merge into
        // the nearest absorber, and a group the merge never touches keeps
        // its member set — only the merged groups' cohorts go dirty.
        let mut cfg = presets::smoke();
        cfg.network.num_aps = 1;
        cfg.network.num_users = 24;
        cfg.optimizer.cohort_users = 8;
        cfg.optimizer.slot_compact_frac = 0.25; // thresh = ⌊8·¼⌋ = 2
        let net = Network::generate(&cfg, 21);
        let load = ChannelLoad::new(1, cfg.network.num_subchannels, 3);
        let mut active = vec![true; net.num_users()];
        let mut table = SlotTable::default();
        let before = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);
        assert_eq!(before.len(), 3);
        let g2_users = before[2].1.users.clone();

        // deplete groups 0 and 1 to two members each; group 2 stays full
        let ap0 = net.topo.users_of_ap(0);
        for (slot, &u) in ap0.iter().enumerate() {
            if matches!(slot, 2..=7 | 10..=15) {
                active[u] = false;
            }
        }
        let after = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);
        // group 0 (occ 2) merged into group 1 (occ 2 → 4); group 2 untouched
        assert_eq!(after.len(), 2);
        assert_eq!(after[0].0, 1);
        let expect: Vec<usize> = [0usize, 1, 8, 9].iter().map(|&s| ap0[s]).collect();
        assert_eq!(after[0].1.users, expect);
        assert_eq!(after[1].0, 2);
        assert_eq!(
            after[1].1.users, g2_users,
            "the untouched group keeps its member set"
        );

        // control: with compaction off the same churn leaves 3 fragments
        let mut cfg_off = cfg.clone();
        cfg_off.optimizer.slot_compact_frac = 0.0;
        let mut t2 = SlotTable::default();
        let all = vec![true; net.num_users()];
        let _ = form_cohorts_stable(&cfg_off, &net, &load, Some(&all), &mut t2);
        let frag = form_cohorts_stable(&cfg_off, &net, &load, Some(&active), &mut t2);
        assert_eq!(frag.len(), 3, "no compaction ⇒ fragments persist");
    }

    #[test]
    fn compaction_bounds_cohort_count_under_sustained_departure_skew() {
        // §2f acceptance: a departure skew that strands every group at ¼
        // occupancy compacts back to the ideal ⌈active / k⌉ group count
        // instead of drifting — 8 groups × 2 survivors → 2 full groups.
        let mut cfg = presets::smoke();
        cfg.network.num_aps = 1;
        cfg.network.num_users = 64;
        cfg.optimizer.cohort_users = 8;
        cfg.optimizer.slot_compact_frac = 0.25;
        let net = Network::generate(&cfg, 22);
        let load = ChannelLoad::new(1, cfg.network.num_subchannels, 3);
        let mut table = SlotTable::default();
        let all = vec![true; net.num_users()];
        let seeded = form_cohorts_stable(&cfg, &net, &load, Some(&all), &mut table);
        assert_eq!(seeded.len(), 8);

        // keep only the two lowest slots of every group
        let ap0 = net.topo.users_of_ap(0);
        let mut active = vec![false; net.num_users()];
        let mut kept = Vec::new();
        for g in 0..8usize {
            for s in [8 * g, 8 * g + 1] {
                active[ap0[s]] = true;
                kept.push(ap0[s]);
            }
        }
        let after = form_cohorts_stable(&cfg, &net, &load, Some(&active), &mut table);
        let ideal = kept.len().div_ceil(cfg.optimizer.cohort_users);
        assert_eq!(after.len(), ideal, "compaction reaches the ideal count");
        let mut members: Vec<usize> =
            after.iter().flat_map(|(_, c)| c.users.clone()).collect();
        members.sort_unstable();
        kept.sort_unstable();
        assert_eq!(members, kept, "no member lost or duplicated");
        for (_, c) in &after {
            assert_eq!(c.users.len(), cfg.optimizer.cohort_users, "merged groups are full");
        }
        // the table really shrank: the merge chain lands everyone in
        // groups 1 and 5, and the trailing holes truncate behind them
        assert_eq!(table.slots_of_ap(0), 48);

        // control: without compaction one fragment per group persists
        let mut cfg_off = cfg.clone();
        cfg_off.optimizer.slot_compact_frac = 0.0;
        let mut t2 = SlotTable::default();
        let _ = form_cohorts_stable(&cfg_off, &net, &load, Some(&all), &mut t2);
        let frag = form_cohorts_stable(&cfg_off, &net, &load, Some(&active), &mut t2);
        assert_eq!(frag.len(), 8, "no compaction ⇒ one fragment per group");
    }
}
