//! Sharded per-AP planner (DESIGN.md §2g).
//!
//! One [`Shard`] per access point owns everything that AP needs to plan:
//! a compact single-cell [`Network`] holding only its (ever-admitted)
//! members' gain rows at that one AP, a [`PlanCache`] with slot table and
//! §2f rate cache, the activity mask, and the committed per-channel power
//! it exports to the rest of the system. Epochs plan **shard-parallel** on
//! the persistent worker pool; cross-cell coupling flows through a cheap
//! background-exchange step — each shard publishes its committed uplink /
//! downlink power per channel, and receives the other shards' power
//! attenuated by the AP-pair path-loss matrix as an
//! [`ExtBackground`](super::cache::ExtBackground) injected into its next
//! plan. The exchange signature is quantized with the same §2e relative
//! buckets as the background fingerprint, so sub-tolerance drift in a
//! neighbor's plan does not dirty a clean shard.
//!
//! Scaling properties:
//!
//! - **O(dirty shards) epochs.** A churn-quiet shard whose quantized
//!   exchange signature is unchanged is skipped entirely — its previous
//!   decisions stand. One handoff dirties exactly the source and
//!   destination shards (pinned by a test below).
//! - **O(active users) memory.** Driven from a
//!   [`UserArena`](crate::net::UserArena), a shard materializes a member's
//!   position/profile/gain row only on admission; the population at large
//!   costs one `usize` each (the association vector).
//! - **Deterministic in the thread count.** All shard inputs (events,
//!   exchange) are fixed before the parallel plan step; each shard plans
//!   sequentially within itself and results are committed per shard, so
//!   1 thread and N threads produce byte-identical decisions (pinned by a
//!   test below).
//!
//! Approximations versus the monolithic planner, by design: the exchange
//! is **lagged** one epoch (shards see neighbors' *previous* committed
//! power — the standard fixed-point iteration of distributed interference
//! coordination), uses the **far-field** AP-pair attenuation instead of
//! per-user cross gains, and the §2f realized-rate/regret pass runs
//! intra-shard (remote power is a planning constant, not a rate term).
//! Sharded plans are therefore *not* byte-identical to `plan_era_cached`
//! on the full network — they are an equally feasible plan of the same
//! structure whose per-shard cost no longer depends on the system size.
//!
//! Local slots are **never recycled**: a departed member keeps its slot
//! (and its gain row) and reclaims it verbatim on return. Member-set cache
//! keys under `trust_static` must never collide across physical users, and
//! a returning user replaying its old slot keeps its cohort identity.
//! Resident memory is thus O(ever-admitted members per shard) — bounded by
//! O(active) for the churn processes used here, where returns reuse rows.

use std::collections::HashMap;
use std::sync::Mutex;

use super::cache::{bg_quantize, ExtBackground};
use super::{plan_era_cached, PlanCache, PlanOptions, PlanStats};
use crate::baselines::Decision;
use crate::config::{ApProfile, Config};
use crate::models::ModelProfile;
use crate::net::{ap_attenuation_of, ChannelState, Network, Pos, Topology, UserArena, UserProfile};
use crate::trace::{ChurnEvent, ChurnEventKind};
use crate::util::pool;

/// Where shards materialize members from: a dense pre-generated
/// [`Network`] (test scale — the same universe the monolithic planner
/// sees) or a lazy [`UserArena`] (million-user scale — records exist only
/// while admitted).
pub enum ShardSource<'a> {
    Net(&'a Network),
    Arena(&'a UserArena),
}

impl<'a> ShardSource<'a> {
    pub fn num_users(&self) -> usize {
        match self {
            ShardSource::Net(n) => n.num_users(),
            ShardSource::Arena(a) => a.num_users(),
        }
    }

    /// Home association of the whole population (the planner's one
    /// O(population) structure).
    pub fn user_aps(&self) -> Vec<usize> {
        match self {
            ShardSource::Net(n) => n.topo.user_ap.clone(),
            ShardSource::Arena(a) => a.user_aps(),
        }
    }

    fn num_aps(&self) -> usize {
        match self {
            ShardSource::Net(n) => n.topo.num_aps(),
            ShardSource::Arena(a) => a.num_aps(),
        }
    }

    fn ap_positions(&self) -> Vec<Pos> {
        match self {
            ShardSource::Net(n) => n.topo.ap_pos.clone(),
            ShardSource::Arena(a) => a.ap_pos.clone(),
        }
    }

    fn attenuation(&self, alpha: f64) -> Vec<Vec<f64>> {
        match self {
            ShardSource::Net(n) => ap_attenuation_of(&n.topo, alpha),
            ShardSource::Arena(a) => a.ap_attenuation(),
        }
    }

    /// Materialize `user`'s shard-local data at `ap`:
    /// `(pos, profile, up_gains, down_gains)`.
    fn member(&self, user: usize, ap: usize) -> (Pos, UserProfile, Vec<f64>, Vec<f64>) {
        match self {
            ShardSource::Net(n) => (
                n.topo.user_pos[user],
                n.users[user].clone(),
                n.channels.up[user][ap].clone(),
                n.channels.down[user][ap].clone(),
            ),
            ShardSource::Arena(a) => {
                let rec = a.user(user);
                let (up, down) = a.link_to(user, &rec.pos, ap);
                (rec.pos, rec.profile, up, down)
            }
        }
    }
}

/// One AP's planning island.
struct Shard {
    /// Physical AP index this shard owns.
    ap: usize,
    /// Single-cell config: `num_aps = 1`, `num_users` tracks the local
    /// slot count, `stable_cohorts` forced on (member-set identity is what
    /// makes churn inside the shard O(touched cohorts)). Carries this AP's
    /// resolved fleet parameters (§2j) — pool size, device-FLOPs range,
    /// bandwidth, cell radius — in place of the globals.
    cfg: Config,
    /// The resolved fleet profile this shard was provisioned from.
    profile: ApProfile,
    /// Append-only single-AP network of ever-admitted members.
    net: Network,
    cache: PlanCache,
    /// Activity per local slot.
    active: Vec<bool>,
    /// Local slot → global user id.
    global_of: Vec<usize>,
    /// Global user id → local slot. Slots are never recycled (see module
    /// docs).
    slot_of: HashMap<usize, usize>,
    /// Last plan's decisions, indexed by local slot.
    decisions: Vec<Decision>,
    stats: PlanStats,
    /// Published committed uplink tx power per channel (Σ p_up of members
    /// assigned that up-channel) from the last plan.
    up_out: Vec<f64>,
    /// Published committed downlink tx power per channel.
    down_out: Vec<f64>,
    /// Quantized signature of the last *applied* [`ExtBackground`];
    /// initialized to the signature of all-zero ext so the first exchange
    /// of a quiet system dirties nothing.
    ext_sig: Vec<i64>,
    dirty: bool,
}

/// Overwrite a shard config's per-AP knobs with one resolved profile.
/// A homogeneous fleet resolves to values bit-equal to the globals, so
/// this is then the identity — shard behavior (and its cache
/// fingerprints) are byte-identical to the pre-fleet planner.
fn apply_profile(cfg: &mut Config, p: &ApProfile) {
    cfg.compute.edge_pool_units = p.edge_pool_units;
    cfg.compute.device_flops_lo = p.device_flops_lo;
    cfg.compute.device_flops_hi = p.device_flops_hi;
    cfg.network.bandwidth_hz = p.bandwidth_hz;
    cfg.network.cell_radius_m = p.cell_radius_m;
}

impl Shard {
    fn new(
        global_cfg: &Config,
        ap: usize,
        ap_pos: Pos,
        profile: &ApProfile,
        full_rescan_every: usize,
    ) -> Self {
        let m = global_cfg.network.num_subchannels;
        let mut cfg = global_cfg.clone();
        cfg.network.num_aps = 1;
        cfg.network.num_users = 0;
        cfg.optimizer.stable_cohorts = true;
        // The shard *is* one resolved profile: its single-AP config and
        // network carry the profile's values directly (and no [fleet.*]
        // sections of their own), so everything downstream — the DES
        // pool, cohort formation, cache fingerprints — sees this AP's
        // parameters without re-deriving from the globals.
        cfg.fleet.clear();
        apply_profile(&mut cfg, profile);
        let net = Network {
            topo: Topology {
                ap_pos: vec![ap_pos],
                user_pos: Vec::new(),
                user_ap: Vec::new(),
                dist: Vec::new(),
            },
            channels: ChannelState {
                up: Vec::new(),
                down: Vec::new(),
                num_subchannels: m,
            },
            users: Vec::new(),
            subchannel_bw: vec![profile.subchannel_bw_hz],
            noise: vec![profile.noise_w],
        };
        let mut cache = PlanCache::new(full_rescan_every, cfg.optimizer.replan_layer_window);
        cache.trust_static = true;
        Self {
            ap,
            cfg,
            profile: profile.clone(),
            net,
            cache,
            active: Vec::new(),
            global_of: Vec::new(),
            slot_of: HashMap::new(),
            decisions: Vec::new(),
            stats: PlanStats::default(),
            up_out: vec![0.0; m],
            down_out: vec![0.0; m],
            ext_sig: vec![i64::MIN; 2 * m],
            dirty: false,
        }
    }

    /// Activate `user`, admitting (materializing) it on first contact.
    fn activate(&mut self, user: usize, source: &ShardSource, model: &ModelProfile) {
        if let Some(&s) = self.slot_of.get(&user) {
            if !self.active[s] {
                self.active[s] = true;
                self.dirty = true;
            }
            return;
        }
        let (pos, profile, up, down) = source.member(user, self.ap);
        let d = pos.dist(&self.net.topo.ap_pos[0]).max(self.cfg.network.min_distance_m);
        let s = self.net.topo.user_pos.len();
        self.net.topo.user_pos.push(pos);
        self.net.topo.user_ap.push(0);
        self.net.topo.dist.push(vec![d]);
        self.net.channels.up.push(vec![up]);
        self.net.channels.down.push(vec![down]);
        self.net.users.push(profile);
        self.cfg.network.num_users = s + 1;
        self.active.push(true);
        self.global_of.push(user);
        self.slot_of.insert(user, s);
        self.decisions.push(Decision::device_only(model));
        self.dirty = true;
    }

    fn deactivate(&mut self, user: usize) {
        if let Some(&s) = self.slot_of.get(&user) {
            if self.active[s] {
                self.active[s] = false;
                self.dirty = true;
            }
        }
    }

    fn any_active(&self) -> bool {
        self.active.iter().any(|&a| a)
    }

    /// Plan this shard (sequential within the shard; shards are the
    /// parallel unit) and republish its committed power.
    fn plan(&mut self, model: &ModelProfile, warm_start: bool) {
        let m = self.cfg.network.num_subchannels;
        if !self.any_active() {
            // Trivial island: no members to plan, nothing exported. Skip
            // `plan_era_cached` entirely — an empty cache would force a
            // (vacuous) full re-scan every epoch.
            for d in &mut self.decisions {
                *d = Decision::device_only(model);
            }
            self.stats = PlanStats::default();
            self.up_out = vec![0.0; m];
            self.down_out = vec![0.0; m];
            self.dirty = false;
            return;
        }
        let popts = PlanOptions {
            warm_start,
            threads: 1,
        };
        let (ds, stats) = plan_era_cached(
            &self.cfg,
            &self.net,
            model,
            &self.active,
            &popts,
            &mut self.cache,
        );
        let mut up_out = vec![0.0; m];
        let mut down_out = vec![0.0; m];
        for (s, d) in ds.iter().enumerate() {
            if !self.active[s] {
                continue;
            }
            if let Some(ch) = d.up_ch {
                up_out[ch] += d.p_up;
            }
            if let Some(ch) = d.down_ch {
                down_out[ch] += d.p_down;
            }
        }
        self.decisions = ds;
        self.stats = stats;
        self.up_out = up_out;
        self.down_out = down_out;
        self.dirty = false;
    }
}

/// Per-epoch planning report.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ShardEpoch {
    /// Shards re-planned this epoch (churn-dirty or exchange-dirty).
    pub planned: usize,
    /// Shards skipped clean (previous decisions stand).
    pub skipped: usize,
    /// Shards dirtied by the background exchange alone this epoch
    /// (counted inside `planned`).
    pub exchange_dirtied: usize,
    /// Cohort solves across all planned shards.
    pub cohorts_resolved: usize,
    /// Cohorts replayed from shard caches.
    pub cohorts_reused: usize,
}

/// The sharded coordinator: routes churn events to shards, runs the
/// quantized background exchange, and plans dirty shards in parallel.
pub struct ShardedPlanner {
    shards: Vec<Mutex<Shard>>,
    /// Current AP association per global user (updated by handoffs).
    user_ap: Vec<usize>,
    /// AP-pair far-field attenuation, `xg[src][dst]`, diagonal 0.
    xg: Vec<Vec<f64>>,
    model: ModelProfile,
    warm_start: bool,
    /// Exchange quantization tolerance (the §2e bucket width); falls back
    /// to a fine default when `bg_tolerance` is disabled so the signature
    /// never divides by `ln(1) = 0`.
    tol: f64,
    m: usize,
}

/// Exclusive shard access outside the parallel planning section.
/// `Mutex::get_mut` can only fail when a solver thread panicked while
/// holding the lock; there is nothing sane to do but propagate the panic.
fn shard_mut(cell: &mut Mutex<Shard>) -> &mut Shard {
    // era-lint: allow(panic) — poison means a solver thread already panicked; propagate it
    cell.get_mut().unwrap()
}

impl ShardedPlanner {
    pub fn new(
        cfg: &Config,
        source: &ShardSource,
        model: &ModelProfile,
        full_rescan_every: usize,
        warm_start: bool,
    ) -> Self {
        let ap_pos = source.ap_positions();
        // one fleet resolution for the whole planner; each shard keeps its
        // own AP's profile (§2j)
        let profiles = cfg
            .ap_profiles()
            .expect("fleet resolution checked by Config::validate");
        debug_assert_eq!(profiles.len(), source.num_aps());
        let shards = (0..source.num_aps())
            .map(|ap| {
                Mutex::new(Shard::new(cfg, ap, ap_pos[ap], &profiles[ap], full_rescan_every))
            })
            .collect();
        Self {
            shards,
            user_ap: source.user_aps(),
            xg: source.attenuation(cfg.network.path_loss_exp),
            model: model.clone(),
            warm_start,
            tol: if cfg.optimizer.bg_tolerance > 0.0 {
                cfg.optimizer.bg_tolerance
            } else {
                1e-6
            },
            m: cfg.network.num_subchannels,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The resolved fleet profile shard `ap` is currently provisioned with.
    pub fn profile_of(&self, ap: usize) -> ApProfile {
        self.shards[ap].lock().unwrap().profile.clone()
    }

    /// Re-provision one AP in place (a §2j fleet re-profile: pool upgrade,
    /// carrier re-assignment, antenna swap). Applies the profile to the
    /// shard's single-AP config and network, rescales the admitted
    /// members' resident gain rows by the antenna-gain ratio (rows fold
    /// the gain in at materialization), and drops the shard's plan cache —
    /// every cached solve was computed under the old parameters, and
    /// trust-static fingerprints trust membership alone, so a stale entry
    /// would otherwise replay verbatim. Exactly this shard goes dirty;
    /// neighbors re-plan only if the lagged exchange later observes a
    /// material committed-power drift (the usual §2e criterion).
    pub fn set_profile(&mut self, ap: usize, profile: &ApProfile) {
        let m = self.m;
        let s = shard_mut(&mut self.shards[ap]);
        let scale = profile.gain / s.profile.gain;
        if scale != 1.0 {
            for rows in s
                .net
                .channels
                .up
                .iter_mut()
                .chain(s.net.channels.down.iter_mut())
            {
                for g in rows[0].iter_mut() {
                    *g *= scale;
                }
            }
        }
        apply_profile(&mut s.cfg, profile);
        s.net.subchannel_bw[0] = profile.subchannel_bw_hz;
        s.net.noise[0] = profile.noise_w;
        s.profile = profile.clone();
        let mut cache = PlanCache::new(s.cache.full_rescan_every, s.cache.window);
        cache.trust_static = true;
        s.cache = cache;
        // all-zero ext signature, matching the fresh cache's zero ext
        s.ext_sig = vec![i64::MIN; 2 * m];
        s.dirty = true;
    }

    /// Activate `user` in its current shard (initial population, or an
    /// `Arrive` churn event).
    pub fn activate(&mut self, source: &ShardSource, user: usize) {
        let ap = self.user_ap[user];
        shard_mut(&mut self.shards[ap]).activate(user, source, &self.model);
    }

    /// Route one churn event. `RateChange` is workload-only — the planner
    /// ignores it. A handoff deactivates the user in its source shard and
    /// activates it in the destination: exactly two shards go dirty.
    pub fn apply_event(&mut self, source: &ShardSource, ev: &ChurnEvent) {
        match ev.kind {
            ChurnEventKind::Arrive => self.activate(source, ev.user),
            ChurnEventKind::Depart => {
                let ap = self.user_ap[ev.user];
                shard_mut(&mut self.shards[ap]).deactivate(ev.user);
            }
            ChurnEventKind::RateChange { .. } => {}
            ChurnEventKind::Handoff { ap } => {
                let from = self.user_ap[ev.user];
                if ap == from {
                    return;
                }
                shard_mut(&mut self.shards[from]).deactivate(ev.user);
                self.user_ap[ev.user] = ap;
                shard_mut(&mut self.shards[ap]).activate(ev.user, source, &self.model);
            }
        }
    }

    pub fn apply_events(&mut self, source: &ShardSource, events: &[ChurnEvent]) {
        for ev in events {
            self.apply_event(source, ev);
        }
    }

    /// Run one planning epoch: exchange last epoch's committed background,
    /// then plan every dirty shard in parallel (`threads ≤ 1` = inline).
    /// Clean shards keep their previous decisions verbatim.
    pub fn plan_epoch(&mut self, threads: usize) -> ShardEpoch {
        let n = self.shards.len();
        // 1. Gather last epoch's published power (cheap: O(APs × channels)).
        let outs: Vec<(Vec<f64>, Vec<f64>)> = self
            .shards
            .iter_mut()
            .map(|s| {
                let s = shard_mut(s);
                (s.up_out.clone(), s.down_out.clone())
            })
            .collect();
        // 2. Exchange: receiver `a` sees every other shard's power through
        //    the AP-pair attenuation. Apply only when the quantized
        //    signature moved — sub-tolerance neighbor drift keeps a clean
        //    shard clean (same bucket scheme as the §2e fingerprint).
        let mut exchange_dirtied = 0usize;
        for a in 0..n {
            let mut ext = ExtBackground {
                up: vec![0.0; self.m],
                down: vec![0.0; self.m],
            };
            for (s, (up, down)) in outs.iter().enumerate() {
                if s == a {
                    continue;
                }
                let g = self.xg[s][a];
                for ch in 0..self.m {
                    ext.up[ch] += up[ch] * g;
                    ext.down[ch] += down[ch] * g;
                }
            }
            let sig: Vec<i64> = ext
                .up
                .iter()
                .chain(ext.down.iter())
                .map(|&v| bg_quantize(v, self.tol))
                .collect();
            let shard = shard_mut(&mut self.shards[a]);
            if sig != shard.ext_sig {
                shard.cache.ext = ext;
                shard.ext_sig = sig;
                if !shard.dirty {
                    exchange_dirtied += 1;
                }
                shard.dirty = true;
            }
        }
        // 3. Plan dirty shards in parallel. Inputs are fully fixed before
        //    this step and each shard is an independent island, so the
        //    result is identical for every thread count.
        let dirty: Vec<usize> = (0..n).filter(|&a| shard_mut(&mut self.shards[a]).dirty).collect();
        let model = &self.model;
        let warm = self.warm_start;
        let shards = &self.shards;
        pool::map_indexed(dirty.len(), threads, |k| {
            let mut s = shards[dirty[k]].lock().unwrap();
            s.plan(model, warm);
        });
        let mut report = ShardEpoch {
            planned: dirty.len(),
            skipped: n - dirty.len(),
            exchange_dirtied,
            ..ShardEpoch::default()
        };
        for &a in &dirty {
            let s = shard_mut(&mut self.shards[a]);
            report.cohorts_resolved += s.stats.cohorts_resolved;
            report.cohorts_reused += s.stats.cohorts_reused;
        }
        report
    }

    /// Current AP association of a global user.
    pub fn ap_of(&self, user: usize) -> usize {
        self.user_ap[user]
    }

    /// The current decision for a global user (device-only when inactive
    /// or never admitted).
    pub fn decision_of(&self, user: usize) -> Decision {
        let shard = self.shards[self.user_ap[user]].lock().unwrap();
        match shard.slot_of.get(&user) {
            Some(&s) if shard.active[s] => shard.decisions[s],
            _ => Decision::device_only(&self.model),
        }
    }

    /// Realized `(up, down)` NOMA rates for a global user from its shard's
    /// §2f rate cache (None before the first plan, when inactive, or when
    /// the shard has no offloaders).
    pub fn rates_of(&self, user: usize) -> Option<(f64, f64)> {
        let shard = self.shards[self.user_ap[user]].lock().unwrap();
        let &s = shard.slot_of.get(&user)?;
        if !shard.active[s] {
            return None;
        }
        shard.cache.rates.as_ref().map(|rc| {
            let r = rc.rates();
            (r.up[s], r.down[s])
        })
    }

    /// Global ids of the users currently *active* in shard `ap`, ascending
    /// — the §2i outage path force-rehomes exactly these. Inactive
    /// residents keep device-only decisions and are left where they are;
    /// moving them would materialize rows in the surviving shards and
    /// break the O(active) memory bound.
    pub fn active_users_of(&self, ap: usize) -> Vec<usize> {
        let s = self.shards[ap].lock().unwrap();
        let mut users: Vec<usize> = s
            .global_of
            .iter()
            .enumerate()
            .filter(|&(slot, _)| s.active[slot])
            .map(|(_, &g)| g)
            .collect();
        users.sort_unstable();
        users
    }

    /// Per-AP active-user counts in one sweep (the §2i rehoming target
    /// choice reads these to balance evacuees across survivors).
    pub fn active_counts(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| {
                let s = s.lock().unwrap();
                s.active.iter().filter(|&&a| a).count()
            })
            .collect()
    }

    /// Currently-active user count across all shards.
    pub fn active_users(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let s = s.lock().unwrap();
                s.active.iter().filter(|&&a| a).count()
            })
            .sum()
    }

    /// Ever-admitted member count (resident rows) across all shards — the
    /// memory-relevant population.
    pub fn resident_users(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().global_of.len()).sum()
    }

    /// `(global user, decision)` for every *active* user, sorted by user —
    /// the byte-identity view the determinism tests compare.
    pub fn decisions_snapshot(&self) -> Vec<(usize, Decision)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let s = s.lock().unwrap();
            for (slot, &g) in s.global_of.iter().enumerate() {
                if s.active[slot] {
                    out.push((g, s.decisions[slot]));
                }
            }
        }
        out.sort_by_key(|&(g, _)| g);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::models;
    use crate::trace::ChurnSchedule;

    fn planner_for(
        cfg: &Config,
        source: &ShardSource,
        model: &ModelProfile,
        active: &[bool],
    ) -> ShardedPlanner {
        let mut p = ShardedPlanner::new(cfg, source, model, 0, true);
        for (u, &a) in active.iter().enumerate() {
            if a {
                p.activate(source, u);
            }
        }
        p
    }

    fn churny_cfg() -> Config {
        let mut cfg = presets::smoke();
        cfg.churn.initial_active_frac = 0.7;
        cfg.churn.arrival_rate_hz = 3.0;
        cfg.churn.departure_rate_hz = 0.15;
        cfg.churn.handoff_hz = 0.1;
        cfg
    }

    /// Tentpole determinism pin: shard-parallel planning is byte-identical
    /// for 1 vs N threads across several churn epochs.
    #[test]
    fn shard_plans_are_thread_count_invariant() {
        let cfg = churny_cfg();
        let net = Network::generate(&cfg, 11);
        let source = ShardSource::Net(&net);
        let model = models::zoo::by_name("nin").unwrap();
        let sched = ChurnSchedule::generate(&cfg, &net.topo.user_ap, 0xBEEF);

        let mut snaps: Vec<Vec<Vec<(usize, Decision)>>> = Vec::new();
        for &threads in &[1usize, 2, 8] {
            let mut p = planner_for(&cfg, &source, &model, &sched.initial_active);
            let mut per_epoch = Vec::new();
            let horizon = [0.25f64, 0.5, 0.75, 1.0];
            let mut cursor = 0usize;
            for &t1 in &horizon {
                while cursor < sched.events.len() && sched.events[cursor].t_s <= t1 {
                    let ev = sched.events[cursor];
                    p.apply_event(&source, &ev);
                    cursor += 1;
                }
                p.plan_epoch(threads);
                per_epoch.push(p.decisions_snapshot());
            }
            snaps.push(per_epoch);
        }
        assert_eq!(snaps[0], snaps[1], "1 vs 2 threads diverged");
        assert_eq!(snaps[0], snaps[2], "1 vs 8 threads diverged");
        // sanity: the run actually planned something
        assert!(snaps[0].iter().any(|s| !s.is_empty()));
    }

    /// Tentpole locality pin: with the exchange quiet (huge tolerance) and
    /// periodic re-scans off, one handoff dirties exactly the source and
    /// destination shards.
    #[test]
    fn handoff_dirties_exactly_two_shards() {
        let mut cfg = presets::smoke();
        cfg.network.num_aps = 4;
        cfg.network.num_users = 48;
        cfg.optimizer.bg_tolerance = 1e9; // exchange never re-dirties
        let net = Network::generate(&cfg, 5);
        let source = ShardSource::Net(&net);
        let model = models::zoo::by_name("nin").unwrap();
        let all_active = vec![true; cfg.network.num_users];
        let mut p = planner_for(&cfg, &source, &model, &all_active);

        let first = p.plan_epoch(2);
        // every populated cell plans on the first epoch (a cell the
        // placement left empty has nothing to plan)
        assert!(first.planned >= 2, "first epoch plans populated shards");
        let quiet = p.plan_epoch(2);
        assert_eq!(quiet.planned, 0, "no churn, no exchange drift ⇒ all clean");
        assert_eq!(quiet.skipped, cfg.network.num_aps);

        let user = 0usize;
        let from = p.user_ap[user];
        let to = (from + 1) % cfg.network.num_aps;
        p.apply_event(
            &source,
            &ChurnEvent {
                t_s: 0.1,
                user,
                kind: ChurnEventKind::Handoff { ap: to },
            },
        );
        let after = p.plan_epoch(2);
        assert_eq!(after.planned, 2, "handoff dirties exactly src + dst");
        assert_eq!(p.user_ap[user], to);
        // the moved user keeps a decision in its new shard
        let _ = p.decision_of(user);
    }

    /// §2i locality pin: an AP outage is a mass handoff — the engine
    /// rehomes every stranded user of the dead AP via the same `Handoff`
    /// events, so even a whole-cell evacuation dirties exactly the source
    /// and destination shards, never the bystanders.
    #[test]
    fn ap_outage_mass_rehome_dirties_only_touched_shards() {
        let mut cfg = presets::smoke();
        cfg.network.num_aps = 4;
        cfg.network.num_users = 48;
        cfg.optimizer.bg_tolerance = 1e9; // exchange never re-dirties
        let net = Network::generate(&cfg, 5);
        let source = ShardSource::Net(&net);
        let model = models::zoo::by_name("nin").unwrap();
        let all_active = vec![true; cfg.network.num_users];
        let mut p = planner_for(&cfg, &source, &model, &all_active);
        p.plan_epoch(2);
        assert_eq!(p.plan_epoch(2).planned, 0, "settled before the outage");

        // AP 0 goes down: every one of its users is force-rehomed to AP 1
        let stranded: Vec<usize> = (0..cfg.network.num_users)
            .filter(|&u| p.user_ap[u] == 0)
            .collect();
        assert!(stranded.len() > 1, "a mass flood, not a single handoff");
        for &u in &stranded {
            p.apply_event(
                &source,
                &ChurnEvent {
                    t_s: 0.1,
                    user: u,
                    kind: ChurnEventKind::Handoff { ap: 1 },
                },
            );
        }
        let after = p.plan_epoch(2);
        assert_eq!(after.planned, 2, "outage dirties exactly src + dst");
        assert_eq!(after.skipped, cfg.network.num_aps - 2);
        for &u in &stranded {
            assert_eq!(p.user_ap[u], 1);
            let _ = p.decision_of(u);
        }
    }

    /// §2j locality pin: re-provisioning one AP's fleet profile dirties
    /// exactly that shard — with the cache dropped, its cohorts all
    /// re-solve once, and nothing else in the system re-plans.
    #[test]
    fn profile_edit_dirties_exactly_that_shard() {
        let mut cfg = presets::smoke();
        cfg.network.num_aps = 4;
        cfg.network.num_users = 48;
        cfg.optimizer.bg_tolerance = 1e9; // exchange never re-dirties
        let net = Network::generate(&cfg, 5);
        let source = ShardSource::Net(&net);
        let model = models::zoo::by_name("nin").unwrap();
        let all_active = vec![true; cfg.network.num_users];
        let mut p = planner_for(&cfg, &source, &model, &all_active);
        p.plan_epoch(2);
        assert_eq!(p.plan_epoch(2).planned, 0, "settled before the re-profile");

        // pick a provably-populated shard (user 0 lives there)
        let ap = p.user_ap[0];
        let row_before = p.shards[ap].lock().unwrap().net.channels.up[0][0].clone();
        let mut prof = p.profile_of(ap);
        prof.edge_pool_units *= 4.0;
        prof.bandwidth_hz *= 2.0;
        prof.subchannel_bw_hz *= 2.0;
        prof.noise_w *= 2.0;
        prof.gain *= 10.0;
        p.set_profile(ap, &prof);

        let after = p.plan_epoch(2);
        assert_eq!(after.planned, 1, "profile edit dirties exactly its shard");
        assert_eq!(after.skipped, cfg.network.num_aps - 1);
        assert_eq!(after.cohorts_reused, 0, "cache dropped ⇒ no stale replays");
        assert!(after.cohorts_resolved >= 1, "the shard's cohorts re-solved");
        {
            let s = p.shards[ap].lock().unwrap();
            assert_eq!(s.cfg.compute.edge_pool_units, prof.edge_pool_units);
            assert_eq!(s.net.subchannel_bw[0], prof.subchannel_bw_hz);
            assert_eq!(s.net.noise[0], prof.noise_w);
            // resident gain rows rescaled by the antenna-gain ratio
            for (a, b) in row_before.iter().zip(&s.net.channels.up[0][0]) {
                assert!((b / a - 10.0).abs() < 1e-9, "row not rescaled: {a} → {b}");
            }
        }
        // quiet again: the huge tolerance swallows the power drift
        assert_eq!(p.plan_epoch(2).planned, 0);
    }

    /// §2j cross-profile handoff pin: moving a user between APs of
    /// *different* profiles re-plans exactly source + destination, and the
    /// destination plans the newcomer under its own parameters (its
    /// profile's bandwidth/noise/pool, not the source's).
    #[test]
    fn cross_profile_handoff_replans_under_destination_parameters() {
        let mut cfg = presets::smoke(); // 2 APs
        cfg.optimizer.bg_tolerance = 1e9;
        cfg.fleet = vec![
            crate::config::FleetProfile {
                name: "a_wide".into(),
                count: 1,
                bandwidth_hz: Some(40e6),
                edge_pool_units: Some(64.0),
                ..crate::config::FleetProfile::default()
            },
            crate::config::FleetProfile {
                name: "b_narrow".into(),
                bandwidth_hz: Some(10e6),
                edge_pool_units: Some(16.0),
                ..crate::config::FleetProfile::default()
            },
        ];
        cfg.validate().unwrap();
        let net = Network::generate(&cfg, 5);
        let source = ShardSource::Net(&net);
        let model = models::zoo::by_name("nin").unwrap();
        let all_active = vec![true; cfg.network.num_users];
        let mut p = planner_for(&cfg, &source, &model, &all_active);
        p.plan_epoch(1);
        assert_eq!(p.plan_epoch(1).planned, 0, "settled before the handoff");
        assert!(p.profile_of(0).subchannel_bw_hz > p.profile_of(1).subchannel_bw_hz);

        let user = (0..cfg.network.num_users)
            .find(|&u| p.user_ap[u] == 0)
            .expect("AP 0 has a member");
        p.apply_event(
            &source,
            &ChurnEvent {
                t_s: 0.1,
                user,
                kind: ChurnEventKind::Handoff { ap: 1 },
            },
        );
        let after = p.plan_epoch(1);
        assert_eq!(after.planned, 2, "cross-profile handoff dirties src + dst");
        assert_eq!(p.ap_of(user), 1);
        let _ = p.decision_of(user);
        // the destination shard plans the newcomer under its own profile
        let s = p.shards[1].lock().unwrap();
        assert_eq!(s.profile.name, "b_narrow");
        assert_eq!(s.cfg.compute.edge_pool_units, 16.0);
        assert_eq!(s.cfg.network.bandwidth_hz, 10e6);
        assert_eq!(
            s.net.subchannel_bw[0],
            10e6 / cfg.network.num_subchannels as f64
        );
    }

    /// Departed users fall back to device-only decisions and return to
    /// their original slot (cache identity survives a depart/arrive cycle).
    #[test]
    fn depart_and_return_reuses_the_slot() {
        let mut cfg = presets::smoke();
        cfg.optimizer.bg_tolerance = 1e9;
        let net = Network::generate(&cfg, 7);
        let source = ShardSource::Net(&net);
        let model = models::zoo::by_name("nin").unwrap();
        let all_active = vec![true; cfg.network.num_users];
        let mut p = planner_for(&cfg, &source, &model, &all_active);
        p.plan_epoch(1);
        let before = p.resident_users();

        let user = 3usize;
        p.apply_event(
            &source,
            &ChurnEvent {
                t_s: 0.1,
                user,
                kind: ChurnEventKind::Depart,
            },
        );
        p.plan_epoch(1);
        let d = p.decision_of(user);
        assert_eq!(d, Decision::device_only(&model), "inactive ⇒ device-only");
        p.apply_event(
            &source,
            &ChurnEvent {
                t_s: 0.2,
                user,
                kind: ChurnEventKind::Arrive,
            },
        );
        p.plan_epoch(1);
        assert_eq!(p.resident_users(), before, "return reuses the slot");
        assert!(p.active_users() == cfg.network.num_users);
    }

    /// An arena-driven planner works end-to-end and only materializes the
    /// users it has admitted.
    #[test]
    fn arena_source_is_o_active_resident() {
        let mut cfg = presets::smoke();
        cfg.network.num_users = 10_000; // population; only a few admitted
        let arena = UserArena::new(&cfg, 31);
        let source = ShardSource::Arena(&arena);
        let model = models::zoo::by_name("nin").unwrap();
        let mut p = ShardedPlanner::new(&cfg, &source, &model, 0, true);
        for u in 0..40 {
            p.activate(&source, u);
        }
        let ep = p.plan_epoch(2);
        assert_eq!(ep.planned + ep.skipped, cfg.network.num_aps);
        assert_eq!(p.resident_users(), 40, "resident = admitted, not population");
        assert_eq!(p.active_users(), 40);
        let offloaders = (0..40)
            .filter(|&u| p.decision_of(u).up_ch.is_some())
            .count();
        // with smoke-scale capacity most of a 40-user cohort offloads
        assert!(offloaders > 0, "arena shard planning produced no offloads");
    }

    /// Neighbor power drift past the tolerance re-dirties via the exchange;
    /// drift below it does not (quantized signature).
    #[test]
    fn exchange_signature_respects_tolerance() {
        let mut cfg = presets::smoke();
        cfg.optimizer.bg_tolerance = 0.25;
        let net = Network::generate(&cfg, 13);
        let source = ShardSource::Net(&net);
        let model = models::zoo::by_name("nin").unwrap();
        let all_active = vec![true; cfg.network.num_users];
        let mut p = planner_for(&cfg, &source, &model, &all_active);
        p.plan_epoch(1);
        // Steady state: planning again with no churn must converge to
        // all-clean within a few exchange rounds (the lagged fixed point).
        let mut planned = usize::MAX;
        for _ in 0..6 {
            let ep = p.plan_epoch(1);
            planned = ep.planned;
            if planned == 0 {
                break;
            }
        }
        assert_eq!(planned, 0, "exchange did not settle under tolerance");
    }
}
