//! System-level integration: full planning → evaluation → serving across
//! strategies and models, checking the paper's qualitative orderings on a
//! deterministic medium-scale scenario.

use era::baselines::*;
use era::config::presets;
use era::coordinator::EraStrategy;
use era::metrics::{evaluate, Outcome};
use era::models::zoo;
use era::net::Network;

fn outcome(
    cfg: &era::config::Config,
    net: &Network,
    model: &era::models::ModelProfile,
    s: &dyn Strategy,
) -> Outcome {
    let ds = s.decide(cfg, net, model);
    evaluate(cfg, net, model, &ds, s.channel_model())
}

fn scaled_medium() -> era::config::Config {
    let mut cfg = presets::medium();
    cfg.network.num_users = 100; // keep the test quick
    cfg.optimizer.max_iters = 80;
    cfg
}

#[test]
fn paper_orderings_hold_fig6_light_load() {
    // At light load ERA deliberately gives back latency headroom once QoE
    // is met (the paper's Fig.2 argument), so the honest assertions are:
    // real speedup, within range of the latency-greedy baseline, and
    // strictly better QoE.
    let cfg = scaled_medium();
    let net = Network::generate(&cfg, 2024);
    let model = zoo::yolov2();
    let dev = outcome(&cfg, &net, &model, &DeviceOnly);
    let era_o = outcome(&cfg, &net, &model, &EraStrategy::default());
    let ns = outcome(&cfg, &net, &model, &Neurosurgeon);
    let eo = outcome(&cfg, &net, &model, &EdgeOnly);

    let s_era = era_o.latency_speedup_vs(&dev);
    let s_ns = ns.latency_speedup_vs(&dev);
    let s_eo = eo.latency_speedup_vs(&dev);
    assert!(s_era > 1.5, "ERA speedup {s_era}");
    assert!(s_era > 0.75 * s_ns, "ERA {s_era} too far below Neurosurgeon {s_ns}");
    assert!(s_ns > s_eo * 0.9, "Neurosurgeon {s_ns} vs EdgeOnly {s_eo}");
    assert!(era_o.qoe.num_violating <= ns.qoe.num_violating);
}

#[test]
fn paper_orderings_hold_fig6_full_load() {
    // Under the paper's congestion regime (250 users / 50 channels) ERA is
    // the best latency speedup outright — Fig.6's ordering.
    let cfg = presets::medium();
    let net = Network::generate(&cfg, cfg.seed);
    let model = zoo::yolov2();
    let dev = outcome(&cfg, &net, &model, &DeviceOnly);
    let s_era = outcome(&cfg, &net, &model, &EraStrategy::default()).latency_speedup_vs(&dev);
    for s in [
        Box::new(Neurosurgeon) as Box<dyn Strategy>,
        Box::new(DnnSurgeon),
        Box::new(Iao::default()),
        Box::new(Dina),
        Box::new(EdgeOnly),
    ] {
        let sp = outcome(&cfg, &net, &model, s.as_ref()).latency_speedup_vs(&dev);
        assert!(s_era > sp, "ERA {s_era} !> {} {sp}", s.name());
    }
}

#[test]
fn era_wins_qoe_against_all_baselines() {
    // The headline claim: the QoE-aware planner satisfies more users.
    let cfg = scaled_medium();
    let net = Network::generate(&cfg, 2025);
    let model = zoo::yolov2();
    let era_o = outcome(&cfg, &net, &model, &EraStrategy::default());
    for s in [
        Box::new(Neurosurgeon) as Box<dyn Strategy>,
        Box::new(DnnSurgeon),
        Box::new(Iao::default()),
        Box::new(EdgeOnly),
        Box::new(DeviceOnly),
    ] {
        let o = outcome(&cfg, &net, &model, s.as_ref());
        assert!(
            era_o.qoe.num_violating <= o.qoe.num_violating,
            "ERA {} violations vs {} {}",
            era_o.qoe.num_violating,
            s.name(),
            o.qoe.num_violating
        );
    }
}

#[test]
fn vgg_speedup_exceeds_lighter_models() {
    // Fig.6: the heaviest model gains the most from offloading.
    let cfg = scaled_medium();
    let net = Network::generate(&cfg, 2026);
    let era = EraStrategy::default();
    let mut speedups = Vec::new();
    for model in [zoo::nin(), zoo::yolov2(), zoo::vgg16()] {
        let dev = outcome(&cfg, &net, &model, &DeviceOnly);
        let o = outcome(&cfg, &net, &model, &era);
        speedups.push((model.name, o.latency_speedup_vs(&dev)));
    }
    let vgg = speedups.iter().find(|s| s.0 == "vgg16").unwrap().1;
    let nin = speedups.iter().find(|s| s.0 == "nin").unwrap().1;
    // NiN has the smallest compute and the largest early cuts — it must
    // gain the least; VGG16 ≈ YOLOv2 cluster above it (paper's Fig.6).
    assert!(vgg >= nin, "vgg {vgg} < nin {nin}");
    for (name, s) in &speedups {
        assert!(vgg >= s * 0.85, "vgg {vgg} vs {name} {s}");
    }
}

#[test]
fn serving_loop_consistent_with_static_eval() {
    // The trace-driven server must agree with the static evaluation on
    // per-user modeled latency.
    let mut cfg = presets::smoke();
    cfg.network.num_users = 30;
    let net = Network::generate(&cfg, 33);
    let model = zoo::nin();
    let (ds, _) = era::coordinator::plan_era(&cfg, &net, &model);
    let o = evaluate(&cfg, &net, &model, &ds, ChannelModel::Noma);
    let (up, down) = era::metrics::rates_for(&cfg, &net, &ds, ChannelModel::Noma);
    let trace = era::trace::fixed_count_trace(&cfg, 1, 5);
    let rep = era::coordinator::server::serve(
        &cfg, &net, &model, &ds, &up, &down, &trace, 2, None, None,
    );
    assert_eq!(rep.modeled_drops, 0);
    for srv in &rep.served {
        // modeled latency is queue-inclusive; net of queueing it must agree
        // with the static evaluation
        let expect = o.delay_s[srv.user];
        assert!(
            (srv.modeled_latency_s - srv.modeled_queue_s - expect).abs() < 1e-9,
            "user {}: served {} (queue {}) vs eval {}",
            srv.user,
            srv.modeled_latency_s,
            srv.modeled_queue_s,
            expect
        );
    }
}

#[test]
fn episode_simulator_conserves_requests_and_orders_time() {
    let mut cfg = presets::smoke();
    cfg.network.num_users = 20;
    let net = Network::generate(&cfg, 44);
    let model = zoo::yolov2();
    let (ds, _) = era::coordinator::plan_era(&cfg, &net, &model);
    let (up, down) = era::metrics::rates_for(&cfg, &net, &ds, ChannelModel::Noma);
    let trace = era::trace::poisson_trace(&cfg, 55);
    let done = era::sim::run_episode(&cfg, &net, &model, &ds, &up, &down, &trace);
    assert_eq!(done.completions.len() + done.dropped.len(), trace.len());
    assert!(done.dropped.is_empty());
    for c in &done.completions {
        assert!(c.finish_s >= c.arrival_s + c.service_s - 1e-9);
        assert!(c.queue_s >= 0.0);
    }
}

#[test]
fn figure_harness_small_scale_smoke() {
    // Every figure id must produce non-empty, finite series at tiny scale.
    let mut h = era::figures::Harness::new(0.1);
    h.cfg.network.num_users = 30;
    h.cfg.network.num_subchannels = 10;
    h.cfg.optimizer.max_iters = 25;
    for fig in [5u32, 6, 8, 10, 12, 14, 15, 16] {
        let figs = h.generate(fig);
        assert!(!figs.is_empty(), "fig {fig} empty");
        for f in &figs {
            for s in &f.series {
                assert!(!s.points.is_empty(), "fig {fig} {} empty", s.name);
                for (x, y) in &s.points {
                    assert!(
                        x.is_finite() && y.is_finite(),
                        "fig {fig} {}: ({x},{y})",
                        s.name
                    );
                }
            }
            // markdown renders
            let md = f.to_markdown();
            assert!(md.contains(&f.id));
        }
    }
}
