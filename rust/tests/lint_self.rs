//! Self-tests for the `era lint` static-analysis pass (ISSUE 8).
//!
//! Three layers:
//!
//! * per-rule firing fixtures through [`era::lint::lint_source`] — every
//!   rule L1–L6 plus the W0 waiver audit must fire on a minimal bad
//!   fixture and stay silent once the idiomatic fix (or a justified
//!   waiver) is applied;
//! * the repo gate: linting this crate's own tree must be clean, because
//!   CI runs `era lint --gate` and a red gate would block every PR;
//! * the binary contract: `--gate` exit codes, `--json` report emission,
//!   and the GitHub annotation format, driven through the real `era`
//!   executable.
//!
//! All bad-code fixtures live inside string literals; the lexer masks
//! string contents, so this file cannot trip the very rules it seeds.

use std::path::Path;
use std::process::Command;

use era::lint::{lint_source, run};

fn codes(findings: &[era::lint::Finding]) -> Vec<(&'static str, usize)> {
    findings.iter().map(|f| (f.rule.code(), f.line)).collect()
}

// ---------------------------------------------------------------- L1 ----

#[test]
fn l1_fires_on_partial_cmp_call() {
    let src = "pub fn pick(a: f64, b: f64) -> bool {\n\
               \x20   a.partial_cmp(&b).is_some()\n\
               }\n";
    let f = lint_source("src/optimizer/pick.rs", src);
    assert_eq!(codes(&f), vec![("L1", 2)]);
    assert!(f[0].message.contains("total_cmp"));
}

#[test]
fn l1_fires_even_in_test_code() {
    // NaN-safe comparison is a correctness property of tests too.
    let src = "#[cfg(test)]\n\
               mod tests {\n\
               \x20   fn t(a: f64, b: f64) { a.partial_cmp(&b); }\n\
               }\n";
    assert_eq!(codes(&lint_source("src/qoe.rs", src)), vec![("L1", 3)]);
}

#[test]
fn l1_ignores_comments_and_trait_impls() {
    let src = "// partial_cmp is mentioned here, and in a string: \"x.partial_cmp(y)\"\n\
               impl PartialOrd for Ev {\n\
               \x20   fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n\
               \x20       Some(self.cmp(other))\n\
               \x20   }\n\
               }\n";
    assert!(lint_source("src/sim/ev.rs", src).is_empty());
}

#[test]
fn l1_waivable_with_justification() {
    let src = "fn pick(a: f64, b: f64) {\n\
               \x20   // era-lint: allow(float-cmp) — inputs proven finite by the caller\n\
               \x20   let _ = a.partial_cmp(&b);\n\
               }\n";
    assert!(lint_source("src/optimizer/pick.rs", src).is_empty());
}

// ---------------------------------------------------------------- L2 ----

#[test]
fn l2_fires_on_hash_iteration_in_determinism_module() {
    let src = "use std::collections::HashMap;\n\
               fn plan() {\n\
               \x20   let m: HashMap<u32, u32> = HashMap::new();\n\
               \x20   for k in m.keys() {\n\
               \x20       let _ = k;\n\
               \x20   }\n\
               }\n";
    let f = lint_source("src/coordinator/plan.rs", src);
    assert_eq!(codes(&f), vec![("L2", 4)]);
    assert!(f[0].message.contains('m'));
}

#[test]
fn l2_silent_outside_determinism_modules_and_on_btree() {
    let src = "use std::collections::HashMap;\n\
               fn report() {\n\
               \x20   let m: HashMap<u32, u32> = HashMap::new();\n\
               \x20   for k in m.keys() {}\n\
               }\n";
    // `report` is not a determinism module: ordering only affects output
    // cosmetics there, and the rule stays scoped to where it is load-bearing.
    assert!(lint_source("src/report/summary.rs", src).is_empty());

    let src = "use std::collections::BTreeMap;\n\
               fn plan() {\n\
               \x20   let m: BTreeMap<u32, u32> = BTreeMap::new();\n\
               \x20   for k in m.keys() {}\n\
               }\n";
    assert!(lint_source("src/coordinator/plan.rs", src).is_empty());
}

#[test]
fn l2_waivable_and_lookup_only_use_is_fine() {
    let src = "use std::collections::HashMap;\n\
               fn plan() {\n\
               \x20   let m: HashMap<u32, u32> = HashMap::new();\n\
               \x20   // era-lint: allow(hash-iter) — folded through an order-insensitive sum\n\
               \x20   let s: u32 = m.values().sum();\n\
               \x20   let _ = (s, m.get(&3));\n\
               }\n";
    assert!(lint_source("src/sim/fold.rs", src).is_empty());
}

// ---------------------------------------------------------------- L3 ----

#[test]
fn l3_fires_on_allocation_in_ws_suffixed_fn() {
    let src = "pub fn solve_step_ws(out: &mut [f64]) {\n\
               \x20   let tmp = vec![0.0; out.len()];\n\
               \x20   out.copy_from_slice(&tmp);\n\
               }\n";
    let f = lint_source("src/optimizer/solve.rs", src);
    assert_eq!(codes(&f), vec![("L3", 2)]);
}

#[test]
fn l3_fires_on_marked_hot_fn_and_respects_waiver() {
    let src = "// era-lint: hot\n\
               fn project(row: &mut [f64]) {\n\
               \x20   let s = format!(\"{row:?}\");\n\
               \x20   drop(s);\n\
               }\n";
    assert_eq!(codes(&lint_source("src/optimizer/p.rs", src)), vec![("L3", 3)]);

    let src = "// era-lint: hot\n\
               fn project(row: &mut [f64]) {\n\
               \x20   // era-lint: allow(hot-alloc) — cold fallback for oversized rows\n\
               \x20   let v = row.to_vec();\n\
               \x20   drop(v);\n\
               }\n";
    assert!(lint_source("src/optimizer/p.rs", src).is_empty());
}

#[test]
fn l3_silent_on_unmarked_fns_and_sanctioned_reuse() {
    // Plain functions may allocate; `resize`/`clear` on caller-owned
    // buffers is the sanctioned workspace idiom even in hot functions.
    let src = "fn build() -> Vec<f64> {\n\
               \x20   vec![0.0; 8]\n\
               }\n\
               // era-lint: hot\n\
               fn step_ws(buf: &mut Vec<f64>, n: usize) {\n\
               \x20   buf.clear();\n\
               \x20   buf.resize(n, 0.0);\n\
               }\n";
    assert!(lint_source("src/optimizer/b.rs", src).is_empty());
}

// ---------------------------------------------------------------- L4 ----

#[test]
fn l4_fires_on_unwrap_in_planner_path() {
    let src = "fn route(xs: &[u32]) -> u32 {\n\
               \x20   *xs.first().unwrap()\n\
               }\n";
    let f = lint_source("src/coordinator/route.rs", src);
    assert_eq!(codes(&f), vec![("L4", 2)]);
}

#[test]
fn l4_exempts_lock_poison_and_tests_and_other_modules() {
    let src = "use std::sync::Mutex;\n\
               fn shared(m: &Mutex<u32>) -> u32 {\n\
               \x20   *m.lock().unwrap()\n\
               }\n\
               #[cfg(test)]\n\
               mod tests {\n\
               \x20   fn t(xs: &[u32]) { xs.first().unwrap(); }\n\
               }\n";
    assert!(lint_source("src/sim/shared.rs", src).is_empty());
    // `net` is a determinism module but not a planner/serving path.
    let src = "fn parse(xs: &[u32]) -> u32 { *xs.first().unwrap() }\n";
    assert!(lint_source("src/net/parse.rs", src).is_empty());
}

#[test]
fn l4_waivable_with_justification() {
    let src = "fn seeded(x: &Option<u32>) -> u32 {\n\
               \x20   // era-lint: allow(panic) — seeded unconditionally two lines above\n\
               \x20   x.expect(\"just seeded\")\n\
               }\n";
    assert!(lint_source("src/coordinator/c.rs", src).is_empty());
}

// ---------------------------------------------------------------- L5 ----

#[test]
fn l5_fires_on_unsafe_without_safety_comment() {
    let src = "fn read(p: *const u32) -> u32 {\n\
               \x20   unsafe { *p }\n\
               }\n";
    let f = lint_source("src/util/raw.rs", src);
    assert_eq!(codes(&f), vec![("L5", 2)]);
}

#[test]
fn l5_satisfied_by_safety_comment_including_impl_pairs() {
    let src = "// SAFETY: all access is serialized behind the owner's mutex\n\
               unsafe impl Send for T {}\n\
               unsafe impl Sync for T {}\n";
    assert!(lint_source("src/util/t.rs", src).is_empty());
}

#[test]
fn l5_exempts_fn_pointer_types() {
    let src = "struct Task {\n\
               \x20   call: unsafe fn(*const (), usize),\n\
               }\n";
    assert!(lint_source("src/util/task.rs", src).is_empty());
}

// ---------------------------------------------------------------- L6 ----

#[test]
fn l6_fires_on_wall_clock_in_determinism_module() {
    let src = "fn stamp() -> std::time::Instant {\n\
               \x20   std::time::Instant::now()\n\
               }\n";
    let f = lint_source("src/sim/stamp.rs", src);
    assert_eq!(codes(&f), vec![("L6", 2)]);
}

#[test]
fn l6_silent_in_benchkit_and_waivable() {
    let src = "fn stamp() { let _ = std::time::Instant::now(); }\n";
    assert!(lint_source("src/benchkit/stamp.rs", src).is_empty());

    let src = "fn stamp() {\n\
               \x20   // era-lint: allow(wall-clock) — telemetry only, never steers the sim\n\
               \x20   let _ = std::time::Instant::now();\n\
               }\n";
    assert!(lint_source("src/sim/stamp.rs", src).is_empty());
}

// ---------------------------------------------------------------- W0 ----

#[test]
fn w0_unjustified_waiver_reports_and_does_not_suppress() {
    let src = "fn route(xs: &[u32]) -> u32 {\n\
               \x20   // era-lint: allow(panic)\n\
               \x20   *xs.first().unwrap()\n\
               }\n";
    let f = lint_source("src/coordinator/route.rs", src);
    assert_eq!(codes(&f), vec![("W0", 2), ("L4", 3)]);
}

#[test]
fn w0_short_justification_and_unknown_key_report() {
    let src = "// era-lint: allow(panic) — ok\n\
               fn f() {}\n";
    let f = lint_source("src/coordinator/x.rs", src);
    assert_eq!(codes(&f), vec![("W0", 1)]);

    let src = "// era-lint: allow(speed) — the justification is long enough here\n\
               fn f() {}\n";
    let f = lint_source("src/coordinator/x.rs", src);
    assert_eq!(codes(&f), vec![("W0", 1)]);
    assert!(f[0].message.contains("unknown"));
}

#[test]
fn waiver_syntax_in_prose_is_not_a_live_annotation() {
    // Doc prose describing the syntax must not register waivers (W0 spam)
    // or hot-marks; only an annotation at the start of a comment counts.
    let src = "//! Write `// era-lint: allow(panic) — reason` above the line.\n\
               //! Mark hot functions with `// era-lint: hot`.\n\
               fn f() {\n\
               \x20   let v = vec![0u8; 4];\n\
               \x20   drop(v);\n\
               }\n";
    assert!(lint_source("src/coordinator/doc.rs", src).is_empty());
}

// ------------------------------------------------- the repo gate --------

#[test]
fn lint_gate_clean_on_this_tree() {
    // CI runs `era lint --gate`; this is the same check in-process so a
    // violation fails `cargo test` locally before it fails the gate.
    let report = run(Path::new(".")).expect("lint walk");
    assert!(report.files_scanned > 40, "scanned {}", report.files_scanned);
    let rendered = era::lint::render_text(&report);
    assert!(report.is_clean(), "era lint found violations:\n{rendered}");
}

// ------------------------------------------------- binary contract ------

fn write_tree(name: &str, files: &[(&str, &str)]) -> std::path::PathBuf {
    let root = std::env::temp_dir().join(format!("era-lint-self-{}-{name}", std::process::id()));
    for (rel, text) in files {
        let path = root.join(rel);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, text).unwrap();
    }
    root
}

fn era_lint(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_era"))
        .arg("lint")
        .args(args)
        .output()
        .expect("spawn era")
}

#[test]
fn gate_exit_codes_and_reports() {
    let clean = write_tree("clean", &[("src/ok.rs", "pub fn ok() -> u32 {\n    1\n}\n")]);
    let out = era_lint(&["--root", clean.to_str().unwrap(), "--gate"]);
    assert!(out.status.success(), "clean tree must pass the gate");

    let bad = "pub fn pick(a: f64, b: f64) -> bool {\n    a.partial_cmp(&b).is_some()\n}\n";
    let dirty = write_tree("dirty", &[("src/sim/pick.rs", bad)]);
    let json = dirty.join("lint.json");
    let out = era_lint(&[
        "--root",
        dirty.to_str().unwrap(),
        "--json",
        json.to_str().unwrap(),
        "--gate",
    ]);
    assert!(!out.status.success(), "dirty tree must fail the gate");

    // GitHub annotation on stdout, machine report on disk.
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("::error file=src/sim/pick.rs,line=2::[L1]"), "got: {stdout}");
    let report = std::fs::read_to_string(&json).unwrap();
    assert!(report.contains("\"format\": \"era-lint-v1\""), "got: {report}");
    assert!(report.contains("\"rule\": \"L1\""));

    // Without --gate the same tree reports but exits 0 (advisory mode).
    let out = era_lint(&["--root", dirty.to_str().unwrap()]);
    assert!(out.status.success(), "advisory run must exit 0");

    for dir in [clean, dirty] {
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn prefix_flag_rewrites_annotation_paths() {
    let bad = "fn read(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
    let tree = write_tree("prefix", &[("src/util/raw.rs", bad)]);
    let out = era_lint(&["--root", tree.to_str().unwrap(), "--prefix", "rust/"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("::error file=rust/src/util/raw.rs,line=2::[L5]"), "got: {stdout}");
    let _ = std::fs::remove_dir_all(tree);
}
