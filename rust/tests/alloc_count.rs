//! Heap-allocation accounting for the Li-GD hot path (ISSUE 2 acceptance:
//! zero heap allocations per GD iteration in the steady state; extended by
//! ISSUE 4 to the masked/incremental re-plan path).
//!
//! This binary installs a counting global allocator and holds a single
//! `#[test]` so no concurrent test can pollute the counter. The contract:
//!
//! * `solve_gd_ws` (the GD iteration loop, including a full workspace
//!   re-`prepare`) performs **zero** allocations once the workspace has
//!   seen the cohort shape;
//! * `solve_ligd_ws` performs a small constant number — exactly the
//!   vectors packaged into the returned `CohortSolution` — independent of
//!   the iteration budget;
//! * a cache-hit `plan_era_cached` epoch (every cohort clean) performs
//!   **zero solver-core work** at steady state: no GD iterations, and its
//!   allocation count is reproducible and independent of the GD budget —
//!   every remaining allocation is plan packaging (decisions, cohort
//!   formation, the rate vectors of the regret pass), none of it scales
//!   with solver effort.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use era::config::presets;
use era::coordinator::{plan_era_cached, plan_era_masked, PlanCache, PlanOptions};
use era::models::zoo;
use era::net::Network;
use era::optimizer::{solve_gd_ws, solve_ligd_ws, CohortProblem, GdOptions, LigdWorkspace};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a pure pass-through to `System` plus a relaxed counter bump; every
// `GlobalAlloc` contract obligation is discharged by `System` itself.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to `System.alloc` with the caller's layout unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: delegates to `System.dealloc`; `ptr`/`layout` come straight
    // from the caller, which `GlobalAlloc` requires to match the allocation.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: delegates to `System.realloc` with all arguments unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: delegates to `System.alloc_zeroed` with the layout unchanged.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

fn cohort_problem() -> CohortProblem {
    let cfg = presets::smoke();
    let net = Network::generate(&cfg, 17);
    let mut users = net.topo.users_of_ap(0);
    if users.len() < 4 {
        users = (0..net.num_users()).collect();
    }
    let users: Vec<usize> = users.into_iter().take(4).collect();
    let channels: Vec<usize> = (0..3).collect();
    let bg_up = vec![1e-15; 3];
    let bg_down = vec![1e-15; users.len() * 3];
    CohortProblem::from_network(&cfg, &net, &users, &channels, bg_up, bg_down)
}

#[test]
fn ligd_hot_path_is_allocation_free_in_steady_state() {
    let model = zoo::nin();
    let opts = GdOptions {
        step_size: 0.05,
        epsilon: 1e-5,
        max_iters: 40,
    };
    let mut p = cohort_problem();
    p.set_uniform_split(&model.split_constants(4));
    let mut ws = LigdWorkspace::new();

    // ---- warm up: first contact with this cohort shape allocates -------
    ws.prepare(&p);
    ws.vars.set_center(&p);
    let warm_rep = solve_gd_ws(&p, &mut ws, &opts);
    assert!(warm_rep.iters >= 1);

    // ---- steady state: full re-prepare + GD solve, zero allocations ----
    let before = allocs();
    ws.prepare(&p);
    ws.vars.set_center(&p);
    let rep = solve_gd_ws(&p, &mut ws, &opts);
    let gd_delta = allocs() - before;
    assert!(rep.iters >= 1);
    assert_eq!(
        gd_delta, 0,
        "solve_gd_ws steady state performed {gd_delta} heap allocations"
    );

    // ---- full Li-GD: constant packaging-only allocation count ----------
    let warmup = solve_ligd_ws(&mut p, &model, &opts, true, &mut ws);
    assert!(warmup.total_iters > 0);

    let before = allocs();
    let sol = solve_ligd_ws(&mut p, &model, &opts, true, &mut ws);
    let short_delta = allocs() - before;
    assert!(sol.total_iters > 0);
    drop(sol);

    let long_opts = GdOptions {
        max_iters: 4 * opts.max_iters,
        ..opts
    };
    let before = allocs();
    let sol = solve_ligd_ws(&mut p, &model, &opts, true, &mut ws);
    let repeat_delta = allocs() - before;
    drop(sol);
    let before = allocs();
    let sol = solve_ligd_ws(&mut p, &model, &long_opts, true, &mut ws);
    let long_delta = allocs() - before;
    assert!(sol.total_iters > 0);
    drop(sol);

    assert_eq!(
        short_delta, repeat_delta,
        "allocation count must be reproducible run-to-run"
    );
    assert_eq!(
        short_delta, long_delta,
        "allocation count must not scale with the iteration budget"
    );
    // Exactly the CohortSolution's owned vectors (10 of them) plus nothing
    // hidden; keep a little headroom for std internals.
    assert!(
        short_delta <= 16,
        "expected packaging-only allocations, got {short_delta}"
    );

    // ---- incremental re-plan: cache-hit epochs do zero solver work -----
    let cfg = presets::smoke();
    let net = Network::generate(&cfg, 23);
    let active: Vec<bool> = (0..net.num_users()).map(|u| u % 2 == 0).collect();
    let popts = PlanOptions::default();
    let mut cache = PlanCache::new(0, cfg.optimizer.replan_layer_window);
    // reference: a full masked re-plan runs the solver every epoch
    let before = allocs();
    let (_, s_full) = plan_era_masked(&cfg, &net, &model, &active, &popts);
    let full_delta = allocs() - before;
    assert!(s_full.total_gd_iters > 0);
    // epoch 0 populates the cache; epoch 1 warms every remaining buffer
    let _ = plan_era_cached(&cfg, &net, &model, &active, &popts, &mut cache);
    let _ = plan_era_cached(&cfg, &net, &model, &active, &popts, &mut cache);

    let before = allocs();
    let (_, s_hit) = plan_era_cached(&cfg, &net, &model, &active, &popts, &mut cache);
    let hit_delta = allocs() - before;
    assert_eq!(s_hit.total_gd_iters, 0, "cache-hit epoch must not run GD");
    assert_eq!(s_hit.cohorts_reused, s_hit.cohorts);
    assert_eq!(s_hit.cohorts_resolved, 0);

    let before = allocs();
    let _ = plan_era_cached(&cfg, &net, &model, &active, &popts, &mut cache);
    let hit_repeat = allocs() - before;
    assert_eq!(
        hit_delta, hit_repeat,
        "cache-hit allocation count must be reproducible"
    );
    // Quadrupling the GD budget must change nothing — the clean path never
    // enters the solver core, so no allocation can scale with it.
    let mut cfg_long = cfg.clone();
    cfg_long.optimizer.max_iters *= 4;
    let before = allocs();
    let (_, s_long) = plan_era_cached(&cfg_long, &net, &model, &active, &popts, &mut cache);
    let hit_long = allocs() - before;
    assert_eq!(s_long.total_gd_iters, 0);
    assert_eq!(
        hit_delta, hit_long,
        "cache-hit allocations must be independent of the GD budget"
    );
    assert!(
        hit_delta < full_delta,
        "cache-hit epoch ({hit_delta} allocs) must be cheaper than a full re-plan ({full_delta})"
    );
}
