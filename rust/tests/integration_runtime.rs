//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not been
//! built (`make artifacts`); CI always builds artifacts first so the full
//! three-layer path is exercised: jax/pallas → HLO text → PJRT → Rust.

use era::optimizer::{CohortProblem, CohortVars};
use era::runtime::{executor::split_cnn_shape, LigdChunkExecutor, Runtime, SplitCnnExecutor};
use std::collections::HashMap;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    // tests run from the crate root
    std::env::var_os("ERA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn skip_if_missing() -> Option<PathBuf> {
    let dir = artifacts_dir();
    if Runtime::artifacts_present(&dir) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Parse the flat `key v1 v2 ...` golden fixture.
fn load_golden(dir: &PathBuf) -> HashMap<String, Vec<f64>> {
    let text = std::fs::read_to_string(dir.join("golden.txt")).expect("golden.txt");
    let mut out = HashMap::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let key = match it.next() {
            Some(k) => k.to_string(),
            None => continue,
        };
        let vals: Vec<f64> = it.map(|v| v.parse().expect("float")).collect();
        out.insert(key, vals);
    }
    out
}

/// Parse `const <name> <value>` lines from the manifest.
fn manifest_consts(dir: &PathBuf) -> HashMap<String, f64> {
    let text = std::fs::read_to_string(dir.join("manifest.txt")).expect("manifest");
    let mut out = HashMap::new();
    for line in text.lines() {
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() == 3 && parts[0] == "const" {
            if let Ok(v) = parts[2].parse::<f64>() {
                out.insert(parts[1].to_string(), v);
            }
        }
    }
    out
}

#[test]
fn manifest_consts_match_rust_defaults() {
    let Some(dir) = skip_if_missing() else { return };
    let c = manifest_consts(&dir);
    let cfg = era::config::Config::default();
    // Relative tolerance — an absolute epsilon silently passes for tiny
    // constants like ξ (≈1e-23), which is exactly where drift bites.
    let close = |a: f64, b: f64| a == b || (a - b).abs() <= 1e-6 * a.abs().max(b.abs());
    assert!(close(c["p_max"], era::util::dbm_to_watt(cfg.network.max_tx_power_dbm)));
    assert!(close(c["p_min"], era::util::dbm_to_watt(cfg.network.min_tx_power_dbm)));
    assert!(close(c["r_min"], cfg.compute.r_min));
    assert!(close(c["r_max"], cfg.compute.r_max));
    assert!(close(c["lambda_gamma"], cfg.compute.lambda_gamma));
    assert!(close(c["edge_unit_flops"], cfg.compute.edge_unit_flops));
    assert!(close(c["xi_device"], cfg.compute.xi_device));
    assert!(close(c["xi_edge"], cfg.compute.xi_edge));
    assert!(close(c["sigmoid_a"], cfg.qoe.sigmoid_a));
    assert!(close(c["w_t"], cfg.optimizer.weight_delay));
    assert!(close(c["w_r"], cfg.optimizer.weight_resource));
    assert!(close(c["w_q"], cfg.optimizer.weight_qoe));
    assert!(close(c["delay_scale"], cfg.optimizer.delay_scale));
    assert!(close(c["energy_scale"], cfg.optimizer.energy_scale));
    assert!(close(c["resource_scale"], cfg.optimizer.resource_scale));
    assert!(close(c["result_bits"], cfg.compute.result_bits));
    assert!(close(c["cohort_users"], cfg.optimizer.cohort_users as f64));
    assert!(close(c["cohort_channels"], cfg.optimizer.cohort_channels as f64));
}

#[test]
fn split_cnn_every_split_matches_golden_logits() {
    let Some(dir) = skip_if_missing() else { return };
    let golden = load_golden(&dir);
    let rt = Runtime::cpu(&dir).expect("pjrt client");
    let (nl, sizes) = split_cnn_shape();
    let exe = SplitCnnExecutor::load(&rt, nl, sizes.clone()).expect("load split cnn");
    let n_in = sizes[0];
    let input: Vec<f32> = (0..n_in)
        .map(|i| i as f32 / (n_in as f32 - 1.0))
        .collect();
    let expect = &golden["logits"];
    for split in 0..=nl {
        let act = exe.run_device(split, &input).expect("device half");
        assert_eq!(act.len(), sizes[split], "cut size at split {split}");
        let logits = exe.run_edge(split, &act).expect("edge half");
        assert_eq!(logits.len(), 10);
        for (i, (&got, &want)) in logits.iter().zip(expect.iter()).enumerate() {
            assert!(
                (got as f64 - want).abs() < 1e-3,
                "split {split} logit {i}: got {got} want {want}"
            );
        }
    }
}

fn cohort_from_golden(golden: &HashMap<String, Vec<f64>>) -> (CohortProblem, CohortVars) {
    let cfg = era::config::Config::default();
    let (u, m) = (
        cfg.optimizer.cohort_users,
        cfg.optimizer.cohort_channels,
    );
    let g = |k: &str| golden[k].clone();
    let link = &golden["link"];
    let p = CohortProblem {
        n_users: u,
        n_channels: m,
        bw_hz: link[0],
        noise_w: link[1],
        g_up: g("g_up"),
        g_down: g("g_down"),
        bg_up: g("bg_up"),
        bg_down: g("bg_down"),
        device_flops: g("c_dev"),
        q_s: g("q_s"),
        f_dev: g("f_dev"),
        f_edge: g("f_edge"),
        w_bits: g("w_bits"),
        result_bits: cfg.compute.result_bits,
        p_min: era::util::dbm_to_watt(cfg.network.min_tx_power_dbm),
        p_max: era::util::dbm_to_watt(cfg.network.max_tx_power_dbm),
        r_min: cfg.compute.r_min,
        r_max: cfg.compute.r_max,
        lambda_gamma: cfg.compute.lambda_gamma,
        edge_unit_flops: cfg.compute.edge_unit_flops,
        xi_device: cfg.compute.xi_device,
        xi_edge: cfg.compute.xi_edge,
        sigmoid_a: cfg.qoe.sigmoid_a,
        w_t: cfg.optimizer.weight_delay,
        w_r: cfg.optimizer.weight_resource,
        w_q: cfg.optimizer.weight_qoe,
        delay_scale: cfg.optimizer.delay_scale,
        energy_scale: cfg.optimizer.energy_scale,
        resource_scale: cfg.optimizer.resource_scale,
    };
    let vars = CohortVars {
        n_users: u,
        n_channels: m,
        x: golden["x0"].clone(),
    };
    (p, vars)
}

#[test]
fn rust_utility_matches_xla_utility() {
    // The cross-implementation oracle: the analytic Rust Γ and the
    // XLA-lowered jax Γ (with the Pallas rate kernel inlined) agree on the
    // golden cohort — both on Γ and on every per-user delay/energy.
    let Some(dir) = skip_if_missing() else { return };
    let golden = load_golden(&dir);
    let (p, vars) = cohort_from_golden(&golden);
    let ev = era::optimizer::eval(&p, &vars, &p.sic_orders());
    let rel = |a: f64, b: f64| (a - b).abs() / (1.0 + a.abs().max(b.abs()));
    assert!(
        rel(ev.total, golden["gamma"][0]) < 2e-4,
        "gamma: rust {} vs xla {}",
        ev.total,
        golden["gamma"][0]
    );
    for i in 0..p.n_users {
        assert!(
            rel(ev.t[i], golden["t"][i]) < 2e-4,
            "t[{i}]: {} vs {}",
            ev.t[i],
            golden["t"][i]
        );
        assert!(
            rel(ev.e[i], golden["e"][i]) < 2e-4,
            "e[{i}]: {} vs {}",
            ev.e[i],
            golden["e"][i]
        );
    }
}

#[test]
fn ligd_chunk_executes_and_descends() {
    // Run the AOT GD chunk from Rust; Γ must match the recorded
    // post-chunk value and be an improvement over the start.
    let Some(dir) = skip_if_missing() else { return };
    let golden = load_golden(&dir);
    let (p, vars) = cohort_from_golden(&golden);
    let rt = Runtime::cpu(&dir).expect("client");
    let exe = LigdChunkExecutor::load(&rt, p.n_users, p.n_channels).expect("chunk");
    let (new_vars, gamma) = exe.run(&p, &vars).expect("run chunk");
    assert!(
        gamma < golden["gamma"][0],
        "chunk did not descend: {gamma} vs start {}",
        golden["gamma"][0]
    );
    let rel = (gamma - golden["gamma_after_chunk"][0]).abs()
        / (1.0 + gamma.abs());
    assert!(
        rel < 2e-3,
        "post-chunk gamma mismatch: rust-run {} vs python-run {}",
        gamma,
        golden["gamma_after_chunk"][0]
    );
    // result is feasible
    for u in 0..p.n_users {
        let su: f64 = (0..p.n_channels).map(|c| new_vars.beta_up(u, c)).sum();
        assert!((su - 1.0).abs() < 1e-3, "beta row sums to {su}");
        assert!(new_vars.r(u) >= p.r_min - 1e-5 && new_vars.r(u) <= p.r_max + 1e-5);
    }
    // And the Rust analytic Γ agrees with the XLA Γ at the new point.
    let ev = era::optimizer::eval(&p, &new_vars, &p.sic_orders());
    assert!(
        (ev.total - gamma).abs() / (1.0 + gamma.abs()) < 2e-3,
        "post-chunk parity: rust {} vs xla {}",
        ev.total,
        gamma
    );
}
