//! Property-based tests over the optimizer and coordinator invariants and
//! the paper's corollaries (in-tree quickcheck kit — no proptest offline).

use era::config::presets;
use era::models::zoo;
use era::net::Network;
use era::optimizer::{solve_gd, solve_ligd, CohortProblem, CohortVars, GdOptions};
use era::util::quickcheck::forall;

fn random_problem(g: &mut era::util::quickcheck::Gen, split: usize) -> CohortProblem {
    let mut cfg = presets::smoke();
    cfg.network.num_users = g.usize_in(12, 30);
    cfg.network.num_aps = g.usize_in(1, 3);
    let net = Network::generate(&cfg, 9_000 + g.case as u64);
    let nu = g.usize_in(2, 6);
    let nc = g.usize_in(2, 6);
    let users: Vec<usize> = (0..nu).collect();
    let channels: Vec<usize> = (0..nc).collect();
    let bg_up = (0..nc).map(|_| g.log_f64_in(1e-17, 1e-13)).collect();
    let bg_down = (0..nu * nc).map(|_| g.log_f64_in(1e-17, 1e-13)).collect();
    let mut p = CohortProblem::from_network(&cfg, &net, &users, &channels, bg_up, bg_down);
    let m = zoo::yolov2();
    p.set_uniform_split(&m.split_constants(split.min(m.num_layers())));
    p
}

#[test]
fn gd_never_increases_utility() {
    // Corollary 2's practical face: every accepted GD step descends.
    forall("GD monotone descent", 24, |g| {
        let split = g.usize_in(0, 17);
        let p = random_problem(g, split);
        let opts = GdOptions {
            step_size: g.log_f64_in(1e-3, 0.2),
            epsilon: 1e-5,
            max_iters: 80,
        };
        let (_, rep) = solve_gd(&p, CohortVars::init_center(&p), &opts);
        assert!(
            rep.final_gamma <= rep.initial_gamma + 1e-9,
            "ascent: {} -> {}",
            rep.initial_gamma,
            rep.final_gamma
        );
    });
}

#[test]
fn gd_solution_always_feasible() {
    forall("GD feasibility", 24, |g| {
        let split = g.usize_in(0, 17);
        let p = random_problem(g, split);
        let opts = GdOptions {
            step_size: 0.05,
            epsilon: 1e-5,
            max_iters: 60,
        };
        let (v, _) = solve_gd(&p, CohortVars::init_center(&p), &opts);
        for u in 0..p.n_users {
            let su: f64 = (0..p.n_channels).map(|m| v.beta_up(u, m)).sum();
            let sd: f64 = (0..p.n_channels).map(|m| v.beta_down(u, m)).sum();
            assert!((su - 1.0).abs() < 1e-6, "beta_up row sum {su}");
            assert!((sd - 1.0).abs() < 1e-6, "beta_down row sum {sd}");
            assert!(v.p_up(u) >= p.p_min - 1e-12 && v.p_up(u) <= p.p_max + 1e-12);
            assert!(v.r(u) >= p.r_min - 1e-12 && v.r(u) <= p.r_max + 1e-12);
        }
    });
}

#[test]
fn ligd_warm_start_no_worse_and_faster_on_average() {
    // Corollary 4: warm-started Li-GD needs fewer total iterations than
    // cold-start GD, without losing solution quality (checked on average
    // across random instances).
    let model = zoo::nin();
    let mut warm_iters = 0usize;
    let mut cold_iters = 0usize;
    let mut warm_gamma = 0.0f64;
    let mut cold_gamma = 0.0f64;
    forall("Li-GD vs cold GD", 8, |g| {
        let p = random_problem(g, 0);
        let opts = GdOptions {
            step_size: 0.05,
            epsilon: 1e-5,
            max_iters: 120,
        };
        let mut pw = p.clone();
        let w = solve_ligd(&mut pw, &model, &opts, true);
        let mut pc = p.clone();
        let c = solve_ligd(&mut pc, &model, &opts, false);
        warm_iters += w.total_iters;
        cold_iters += c.total_iters;
        warm_gamma += w.gamma;
        cold_gamma += c.gamma;
    });
    assert!(
        warm_iters < cold_iters,
        "warm {warm_iters} !< cold {cold_iters}"
    );
    assert!(
        warm_gamma <= cold_gamma * 1.05,
        "warm-start lost quality: {warm_gamma} vs {cold_gamma}"
    );
}

#[test]
fn approximation_error_shrinks_with_sigmoid_sharpness() {
    // Corollary 5's empirical face: the relaxed DCT approaches the exact
    // discrete DCT as `a` grows, across random (T, Q).
    forall("approx error ↓ in a", 128, |g| {
        let t = g.f64_in(0.001, 0.04);
        let q = g.f64_in(0.005, 0.02);
        if (t / q - 1.0).abs() < 0.05 {
            return;
        }
        let exact = era::qoe::dct_exact(t, q);
        let e_small = (era::qoe::dct_relaxed(t, q, 20.0) - exact).abs();
        let e_large = (era::qoe::dct_relaxed(t, q, 2000.0) - exact).abs();
        assert!(
            e_large <= e_small + 1e-12,
            "a=2000 worse than a=20 at t={t} q={q}"
        );
    });
}

#[test]
fn rounding_preserves_feasibility_across_scenarios() {
    // Coordinator invariant under many random networks: rounded plans never
    // violate the NOMA cluster cap, power boxes, or SIC threshold.
    let model = zoo::nin();
    forall("rounded plan feasibility", 8, |g| {
        let mut cfg = presets::smoke();
        cfg.network.num_users = g.usize_in(10, 40);
        cfg.network.num_aps = g.usize_in(1, 4);
        cfg.network.num_subchannels = g.usize_in(4, 12);
        cfg.optimizer.max_iters = 30;
        let net = Network::generate(&cfg, 7_000 + g.case as u64);
        let (ds, _) = era::coordinator::plan_era(&cfg, &net, &model);
        let mut load =
            vec![vec![0usize; cfg.network.num_subchannels]; cfg.network.num_aps];
        let p_max = era::util::dbm_to_watt(cfg.network.max_tx_power_dbm);
        for (u, d) in ds.iter().enumerate() {
            if let Some(ch) = d.up_ch {
                let ap = net.topo.user_ap[u];
                load[ap][ch] += 1;
                assert!(load[ap][ch] <= cfg.network.max_users_per_subchannel);
                assert!(d.p_up <= p_max + 1e-12);
                assert!(
                    d.p_up * net.channels.up[u][ap][ch] > cfg.network.sic_threshold_w,
                    "committed user below SIC threshold"
                );
            }
        }
    });
}

#[test]
fn evaluation_is_deterministic_and_seed_sensitive() {
    let cfg = presets::smoke();
    let model = zoo::yolov2();
    let a = Network::generate(&cfg, 123);
    let b = Network::generate(&cfg, 123);
    let (da, _) = era::coordinator::plan_era(&cfg, &a, &model);
    let (db, _) = era::coordinator::plan_era(&cfg, &b, &model);
    assert_eq!(da, db, "same seed must give identical plans");
    let c = Network::generate(&cfg, 124);
    let (dc, _) = era::coordinator::plan_era(&cfg, &c, &model);
    assert_ne!(da, dc, "different seed should differ");
}

#[test]
fn workspace_reuse_is_bit_identical_to_fresh_solver() {
    // The allocation-free contract: a LigdWorkspace reused across many
    // cohorts (the pool-worker steady state, with stale buffer contents
    // from earlier solves) must produce exactly the CohortSolution a
    // freshly-allocated workspace produces — bit-for-bit, not within
    // tolerance.
    use era::optimizer::{solve_ligd_ws, LigdWorkspace};
    let model = zoo::yolov2();
    let opts = GdOptions {
        step_size: 0.05,
        epsilon: 1e-5,
        max_iters: 60,
    };
    let mut reused = LigdWorkspace::new();
    forall("workspace reuse == fresh alloc", 12, |g| {
        let split = g.usize_in(0, 17);
        let warm_start = g.case % 2 == 0;
        let p = random_problem(g, split);
        let mut p_reused = p.clone();
        let mut p_fresh = p.clone();
        let mut p_tls = p;
        let a = solve_ligd_ws(&mut p_reused, &model, &opts, warm_start, &mut reused);
        let b = solve_ligd_ws(&mut p_fresh, &model, &opts, warm_start, &mut LigdWorkspace::new());
        assert_eq!(a, b, "reused workspace diverged from fresh workspace");
        // the public entry point (thread-local workspace) matches too
        let c = solve_ligd(&mut p_tls, &model, &opts, warm_start);
        assert_eq!(a, c, "thread-local workspace diverged");
    });
}

#[test]
fn solve_gd_workspace_matches_wrapper() {
    use era::optimizer::{solve_gd_ws, LigdWorkspace};
    let mut ws = LigdWorkspace::new();
    forall("solve_gd_ws == solve_gd", 10, |g| {
        let split = g.usize_in(0, 17);
        let p = random_problem(g, split);
        let opts = GdOptions {
            step_size: 0.05,
            epsilon: 1e-5,
            max_iters: 40,
        };
        let init = CohortVars::init_center(&p);
        let (v, rep) = solve_gd(&p, init.clone(), &opts);
        ws.prepare(&p);
        ws.vars.x.copy_from_slice(&init.x);
        let rep2 = solve_gd_ws(&p, &mut ws, &opts);
        assert_eq!(rep, rep2, "reports diverged");
        assert_eq!(v.x, ws.vars.x, "solution points diverged");
        // ws.ev holds the forward at the solution — the no-redundant-eval
        // contract consumed by solve_ligd_ws
        let ev = era::optimizer::eval(&p, &v, &p.sic_orders());
        assert_eq!(ev.total, ws.ev.total);
        assert_eq!(ev.util, ws.ev.util);
        assert_eq!(ev.t, ws.ev.t);
        assert_eq!(ev.e, ws.ev.e);
    });
}
