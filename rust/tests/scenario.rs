//! Integration tests over the scenario layer: spec loading (TOML
//! round-trip, error quality), engine execution, the determinism
//! contract — identical RunRecord rows for every engine thread count —
//! and the dynamic serving engine (churn + epoch re-planning, plus the
//! DES request-conservation guarantees it relies on).

use era::config::presets;
use era::scenario::{expand, to_csv, Engine, RunRecord, ScenarioSpec};

fn grid_spec() -> ScenarioSpec {
    // ≥ 2 strategies × ≥ 2 sweep values × ≥ 2 seeds — the acceptance shape.
    let mut base = presets::smoke();
    base.network.num_users = 16;
    base.optimizer.max_iters = 30;
    ScenarioSpec::new("grid", base)
        .with_strategies(&["era", "neurosurgeon"])
        .with_axis_usize("network.num_users", &[12, 16])
        .with_replicates(2)
}

#[test]
fn full_spec_toml_round_trip() {
    let mut spec = grid_spec().with_axis_str("workload.model", &["nin", "yolov2"]);
    spec.episode = true;
    spec.episode_churn = true;
    spec.replan_interval_s = Some(0.25);
    spec.base.churn.arrival_rate_hz = 2.5;
    spec.trace_seed = Some(99);
    spec.seed_axis = Some("network.num_users".into());
    spec.plan_threads = 3;
    // axes must be in alphabetical key order for text round-trips
    // ("network.num_users" < "workload.model" — already true here)
    let text = spec.to_toml();
    let reparsed = ScenarioSpec::from_str(&text)
        .unwrap_or_else(|e| panic!("round-trip parse failed: {e:#}\n---\n{text}"));
    assert_eq!(reparsed, spec);
    // and a second round is a fixed point
    assert_eq!(reparsed.to_toml(), text);
}

#[test]
fn spec_file_loading_and_errors() {
    let dir = std::env::temp_dir().join("era-scenario-test");
    std::fs::create_dir_all(&dir).unwrap();
    let good = dir.join("good.toml");
    std::fs::write(
        &good,
        r#"
        name = "from-file"
        preset = "smoke"
        strategies = ["era", "device-only"]
        seeds = 2
        [sweep]
        workload.model = ["nin", "yolov2"]
        "#,
    )
    .unwrap();
    let spec = ScenarioSpec::from_path(&good).unwrap();
    assert_eq!(spec.name, "from-file");
    assert_eq!(spec.num_cells(), 2 * 2 * 2);
    // resolve() prefers the file when it exists, else presets
    assert_eq!(
        ScenarioSpec::resolve(good.to_str().unwrap()).unwrap().name,
        "from-file"
    );
    assert_eq!(ScenarioSpec::resolve("smoke-grid").unwrap().name, "smoke-grid");

    // error quality: unknown key, unknown preset, unknown strategy
    let e = ScenarioSpec::from_str("sweeps = 3\n").unwrap_err().to_string();
    assert!(e.contains("unknown scenario key `sweeps`"), "{e}");
    let e = ScenarioSpec::resolve("no-such-preset").unwrap_err().to_string();
    assert!(e.contains("unknown scenario preset `no-such-preset`"), "{e}");
    assert!(e.contains("smoke-grid"), "suggests known presets: {e}");
    let e = ScenarioSpec::from_str("strategies = [\"neurosurgeon2\"]\n")
        .unwrap_err()
        .to_string();
    assert!(e.contains("unknown strategy"), "{e}");
    let e = ScenarioSpec::from_str("[sweep]\nqoe.nope = [1]\n")
        .unwrap_err()
        .to_string();
    assert!(e.contains("qoe.nope"), "{e}");
}

#[test]
fn engine_rows_identical_at_1_and_n_threads() {
    // The determinism contract behind `era run`: every cell derives its
    // randomness from the spec, so the emitted rows are byte-identical
    // regardless of engine parallelism.
    let spec = grid_spec();
    let r1 = Engine::new(1).run(&spec).unwrap();
    let r4 = Engine::new(4).run(&spec).unwrap();
    let r7 = Engine::new(7).run(&spec).unwrap();
    assert_eq!(r1.len(), spec.num_cells());
    let csv1 = to_csv(&r1);
    assert_eq!(csv1, to_csv(&r4), "1 vs 4 threads");
    assert_eq!(csv1, to_csv(&r7), "1 vs 7 threads");
    // sanity: the grid actually exercised both strategies and both seeds
    assert!(r1.iter().any(|r| r.strategy == "era"));
    assert!(r1.iter().any(|r| r.strategy == "neurosurgeon"));
    let seeds: std::collections::HashSet<u64> = r1.iter().map(|r| r.seed).collect();
    assert_eq!(seeds.len(), 2);
}

#[test]
fn grid_covers_every_cell_with_real_results() {
    let spec = grid_spec();
    let cells = expand(&spec).unwrap();
    let records = Engine::new(4).run(&spec).unwrap();
    assert_eq!(records.len(), cells.len());
    for (c, r) in cells.iter().zip(records.iter()) {
        assert_eq!(c.index, r.cell);
        assert_eq!(c.strategy, r.strategy);
        assert_eq!(c.seed, r.seed);
        assert!(r.sum_delay_s > 0.0);
        assert!(r.sum_energy_j > 0.0);
        assert!(r.qoe_users > 0);
        if r.strategy == "era" {
            assert!(r.gd_iters > 0, "ERA cells carry Li-GD stats");
            assert!(r.cohorts > 0);
        }
    }
}

#[test]
fn in_cell_parallel_planning_matches_across_plan_threads() {
    // plan_threads engages wave-parallel Li-GD inside each ERA cell;
    // results must be identical for any plan_threads ≥ 2.
    let mut base = presets::smoke();
    base.network.num_users = 20;
    base.optimizer.max_iters = 30;
    let mk = |t: usize| {
        let mut s = ScenarioSpec::new("p", base.clone()).with_strategies(&["era"]);
        s.plan_threads = t;
        s
    };
    let r2 = Engine::new(1).run_one(&mk(2)).unwrap();
    let r4 = Engine::new(1).run_one(&mk(4)).unwrap();
    assert_eq!(r2.to_csv_row(), r4.to_csv_row());
}

#[test]
fn scenario_presets_smoke_run() {
    // The CI-sized preset end-to-end: the exact path behind
    // `era run --scenario smoke-grid`.
    let spec = ScenarioSpec::from_preset("smoke-grid").unwrap();
    let records = Engine::default().run(&spec).unwrap();
    assert_eq!(records.len(), 8);
    let csv = to_csv(&records);
    assert_eq!(csv.lines().count(), 9);
}

#[test]
fn pooled_engine_rows_match_standalone_cells() {
    // Golden contract for the worker pool + shared-network cache: the
    // engine (any thread count, one Network shared across the strategy
    // axis of a sweep point) emits byte-identical rows to executing every
    // cell standalone, each generating its own network.
    let mut spec = grid_spec();
    spec.plan_threads = 2; // exercise nested pool use inside ERA cells
    let cells = expand(&spec).unwrap();
    let standalone: Vec<String> = cells
        .iter()
        .map(|c| era::scenario::run_cell(&spec, c).unwrap().to_csv_row())
        .collect();
    for threads in [1, 4] {
        let records = Engine::new(threads).run(&spec).unwrap();
        let rows: Vec<String> = records.iter().map(|r| r.to_csv_row()).collect();
        assert_eq!(rows, standalone, "threads={threads}");
    }
}

#[test]
fn saturation_conserves_requests_for_all_strategies() {
    // Regression for the DES silent-loss bug: a trace that over-subscribes
    // `edge_pool_units` (pool far below r_max, compressed episode) must
    // account for every request under every strategy — completed +
    // explicitly-dropped == trace length, and with finite link rates
    // nothing may drop at all.
    let mut cfg = presets::smoke();
    cfg.network.num_users = 16;
    cfg.optimizer.max_iters = 30;
    cfg.compute.edge_pool_units = 2.0; // << r_max = 16: the old starvation case
    cfg.workload.episode_s = 0.02;
    let net = era::net::Network::generate(&cfg, 5);
    let model = era::models::zoo::by_name(&cfg.workload.model).expect("model");
    let tr = era::trace::fixed_count_trace(&cfg, 6, 11);
    for &name in era::strategies::NAMES {
        let strat = era::strategies::by_name(name).expect("strategy");
        let ds = strat.decide(&cfg, &net, &model);
        let (up, down) = era::metrics::rates_for(&cfg, &net, &ds, strat.channel_model());
        let done = era::sim::run_episode(&cfg, &net, &model, &ds, &up, &down, &tr);
        assert_eq!(
            done.completions.len() + done.dropped.len(),
            tr.len(),
            "{name}: conservation"
        );
        assert!(
            done.dropped.is_empty(),
            "{name}: finite-rate requests must complete, not drop"
        );
    }
}

#[test]
fn churn_off_rows_match_the_legacy_static_path() {
    // The byte-identity contract: with churn disabled, an episode grid must
    // take the legacy static path — the CSV header is the legacy column
    // set, and every episode record equals a hand-rolled replay of
    // plan → rates → fixed_count_trace → run_episode → stats.
    let mut spec = grid_spec();
    spec.episode = true;
    spec.trace_seed = Some(99);
    assert!(!spec.is_dynamic());
    let records = Engine::new(2).run(&spec).unwrap();
    let csv = to_csv(&records);
    assert_eq!(
        csv.lines().next().unwrap(),
        RunRecord::csv_header(),
        "churn-off grids keep the legacy header"
    );
    assert!(!csv.contains("dyn_"), "no dynamics columns leak in");

    let cells = expand(&spec).unwrap();
    for (c, r) in cells.iter().zip(records.iter()) {
        let net = era::net::Network::generate(&c.cfg, c.net_seed);
        let strat = era::strategies::by_name(&c.strategy).unwrap();
        let model = era::models::zoo::by_name(&c.cfg.workload.model).unwrap();
        let ds = strat.decide(&c.cfg, &net, &model);
        let (up, down) = era::metrics::rates_for(&c.cfg, &net, &ds, strat.channel_model());
        let k = c.cfg.workload.tasks_per_user.round().max(0.0) as usize;
        let tr = era::trace::fixed_count_trace(&c.cfg, k, 99);
        let done = era::sim::run_episode(&c.cfg, &net, &model, &ds, &up, &down, &tr);
        let st = era::sim::stats(&done.completions, c.cfg.workload.episode_s);
        let ep = r.episode.as_ref().expect("episode record");
        assert_eq!(ep.n, st.n, "cell {}", c.index);
        assert_eq!(ep.mean_latency_s, st.mean_latency_s, "cell {}", c.index);
        assert_eq!(ep.p99_latency_s, st.p99_latency_s, "cell {}", c.index);
        assert_eq!(ep.mean_queue_s, st.mean_queue_s, "cell {}", c.index);
        assert_eq!(ep.dropped, 0, "cell {}", c.index);
        assert!(r.dynamics.is_none(), "cell {}", c.index);
    }
}

#[test]
fn churn_preset_runs_end_to_end_with_dynamics() {
    // CI-sized variant of `era run --scenario churn`: scaled down but same
    // shape (churn schedule + epoch re-planning through every strategy).
    let mut spec = ScenarioSpec::from_preset("churn").unwrap();
    spec.base.network.num_users = 16;
    spec.base.optimizer.max_iters = 25;
    spec.base.workload.episode_s = 0.5;
    spec.base.workload.arrival_rate_hz = 15.0;
    spec.replan_interval_s = Some(0.125);
    spec.strategies = vec!["era".into(), "neurosurgeon".into()];
    spec.axes.clear();
    let records = Engine::new(2).run(&spec).unwrap();
    assert_eq!(records.len(), 2);
    let csv = to_csv(&records);
    assert_eq!(csv.lines().next().unwrap(), RunRecord::csv_header_dynamic());
    for r in &records {
        let ep = r.episode.as_ref().expect("episode");
        let dy = r.dynamics.as_ref().expect("dynamics");
        assert_eq!(dy.epochs.len(), 4, "0.5 s episode / 0.125 s epochs");
        let requests: usize = dy.epochs.iter().map(|e| e.requests).sum();
        let accounted: usize = dy.epochs.iter().map(|e| e.completed + e.dropped).sum();
        assert_eq!(requests, accounted, "{}: epoch conservation", r.strategy);
        assert_eq!(requests, ep.n + ep.dropped, "{}: total conservation", r.strategy);
        if r.strategy == "era" {
            assert!(
                dy.epochs.iter().any(|e| e.gd_iters > 0),
                "era re-plans must run Li-GD"
            );
        }
    }
    // the whole dynamic pipeline is deterministic across engine thread counts
    let again = Engine::new(1).run(&spec).unwrap();
    assert_eq!(csv, to_csv(&again));
}

#[test]
fn density_shaped_grid_is_thread_invariant_across_all_strategies() {
    // The density preset's shape (full strategy list × a user-count axis)
    // at smoke scale: rows must be byte-identical across engine thread
    // counts while all strategies of a sweep point share one cached
    // network. (The full `density` preset is identical modulo scale.)
    let mut base = presets::smoke();
    base.optimizer.max_iters = 25;
    let spec = ScenarioSpec::new("density", base)
        .with_strategies(era::strategies::NAMES)
        .with_axis_usize("network.num_users", &[12, 18]);
    let r1 = Engine::new(1).run(&spec).unwrap();
    let r6 = Engine::new(6).run(&spec).unwrap();
    assert_eq!(to_csv(&r1), to_csv(&r6), "1 vs 6 threads");
    for s in era::strategies::NAMES {
        assert!(r1.iter().any(|r| r.strategy == *s), "missing strategy {s}");
    }
}

#[test]
fn incremental_full_rescan_rows_are_byte_identical_to_full_replan() {
    // Acceptance (ISSUE 4): with `episode.full_rescan_every = 1` every
    // incremental epoch is a forced full re-solve — the emitted CSV rows
    // (cache-statistics columns included) must be byte-identical to the
    // non-incremental dynamic path.
    let mut spec = ScenarioSpec::from_preset("churn").unwrap();
    spec.base.network.num_users = 14;
    spec.base.optimizer.max_iters = 25;
    spec.base.workload.episode_s = 0.5;
    spec.base.workload.arrival_rate_hz = 15.0;
    spec.strategies = vec!["era".into()];
    spec.axes.clear();
    let mut inc = spec.clone();
    inc.incremental = true;
    inc.full_rescan_every = 1;
    let full_csv = to_csv(&Engine::new(2).run(&spec).unwrap());
    let inc_csv = to_csv(&Engine::new(2).run(&inc).unwrap());
    assert_eq!(inc_csv, full_csv, "full_rescan_every=1 ≡ full re-plan");
}

#[test]
fn incremental_churn_off_rows_match_modulo_cache_columns() {
    // Acceptance (ISSUE 4): with churn off, incremental serving results are
    // byte-identical to the full re-plan path — the only columns allowed to
    // differ are the cache-statistics ones (which must then show full
    // reuse: the steady-state epochs replay cached solves verbatim).
    let mut base = presets::smoke();
    base.network.num_users = 14;
    base.optimizer.max_iters = 25;
    base.workload.episode_s = 0.5;
    base.workload.tasks_per_user = 4.0; // replan-only keeps fixed-count
    let mut spec = ScenarioSpec::new("inc-off", base).with_strategies(&["era"]);
    spec.episode = true;
    spec.replan_interval_s = Some(0.125);
    spec.trace_seed = Some(7);
    let mut inc = spec.clone();
    inc.incremental = true;
    let full_csv = to_csv(&Engine::new(1).run(&spec).unwrap());
    let inc_csv = to_csv(&Engine::new(1).run(&inc).unwrap());

    let header: Vec<&str> = full_csv.lines().next().unwrap().split(',').collect();
    assert_eq!(inc_csv.lines().next().unwrap().split(',').count(), header.len());
    let cache_cols = ["dyn_cohorts_reused", "dyn_cohorts_resolved", "dyn_cache_hit_frac"];
    for c in cache_cols {
        assert!(header.contains(&c), "missing column {c}");
    }
    for (fl, il) in full_csv.lines().zip(inc_csv.lines()).skip(1) {
        let fv: Vec<&str> = fl.split(',').collect();
        let iv: Vec<&str> = il.split(',').collect();
        assert_eq!(fv.len(), iv.len());
        for (k, (f, i)) in header.iter().zip(fv.iter().zip(iv.iter())) {
            if cache_cols.contains(k) {
                continue;
            }
            assert_eq!(f, i, "column {k} must be byte-identical");
        }
        // 4 epochs: 1 populate + 3 all-clean ⇒ hit frac 3/4
        let hit_idx = header.iter().position(|k| *k == "dyn_cache_hit_frac").unwrap();
        let hit: f64 = iv[hit_idx].parse().unwrap();
        assert!(hit > 0.7, "steady-state epochs must reuse the cache (hit={hit})");
        let full_hit: f64 = fv[hit_idx].parse().unwrap();
        assert_eq!(full_hit, 0.0, "full path never reuses");
    }
}

#[test]
fn stable_cohorts_churn_off_rows_are_byte_identical_at_the_csv_layer() {
    // Acceptance (ISSUE 5): with churn off, flipping `stable_cohorts` (and
    // a live `bg_tolerance`) must not change a single CSV byte vs the
    // positional incremental path — cache-statistics columns included,
    // since the slot table degrades to chunks and every epoch replays.
    let mut base = presets::smoke();
    base.network.num_users = 14;
    base.optimizer.max_iters = 25;
    base.workload.episode_s = 0.5;
    base.workload.tasks_per_user = 4.0; // replan-only keeps fixed-count
    let mut spec = ScenarioSpec::new("stable-off", base).with_strategies(&["era"]);
    spec.episode = true;
    spec.replan_interval_s = Some(0.125);
    spec.incremental = true;
    spec.trace_seed = Some(7);
    let mut stable = spec.clone();
    stable.base.optimizer.stable_cohorts = true;
    stable.base.optimizer.bg_tolerance = 0.05;
    let pos_csv = to_csv(&Engine::new(1).run(&spec).unwrap());
    let stable_csv = to_csv(&Engine::new(1).run(&stable).unwrap());
    assert_eq!(stable_csv, pos_csv, "stable_cohorts churn-off ≡ positional");
}

#[test]
fn churn_stable_preset_runs_end_to_end() {
    // CI-sized `era run --scenario churn-stable`: the member-set-keyed
    // stable planner survives real churn, conserves requests, and stays
    // deterministic across engine thread counts.
    let mut spec = ScenarioSpec::from_preset("churn-stable").unwrap();
    assert!(spec.base.optimizer.stable_cohorts);
    assert!(spec.base.optimizer.bg_tolerance > 0.0);
    spec.base.network.num_users = 16;
    spec.base.optimizer.max_iters = 25;
    spec.base.workload.episode_s = 0.5;
    spec.base.workload.arrival_rate_hz = 15.0;
    spec.replan_interval_s = Some(0.125);
    spec.strategies = vec!["era".into()];
    spec.axes.clear();
    let records = Engine::new(2).run(&spec).unwrap();
    let csv = to_csv(&records);
    assert!(csv.lines().next().unwrap().contains("dyn_cache_hit_frac"));
    for r in &records {
        let ep = r.episode.as_ref().expect("episode");
        let dy = r.dynamics.as_ref().expect("dynamics");
        let requests: usize = dy.epochs.iter().map(|e| e.requests).sum();
        let accounted: usize = dy.epochs.iter().map(|e| e.completed + e.dropped).sum();
        assert_eq!(requests, accounted, "epoch conservation");
        assert_eq!(requests, ep.n + ep.dropped, "total conservation");
        for e in &dy.epochs {
            assert_eq!(e.cohorts_reused + e.cohorts_resolved, e.cohorts);
        }
    }
    let again = Engine::new(1).run(&spec).unwrap();
    assert_eq!(csv, to_csv(&again), "thread invariance");
}

#[test]
fn fleet_preset_runs_end_to_end_sharded_and_heterogeneous() {
    // CI-sized `era run --scenario fleet` (DESIGN.md §2j): a heterogeneous
    // macro/small fleet swept across composition (`fleet.macro.count`) and
    // execution path (`episode.sharded`) on the same cells. Every cell —
    // monolithic and sharded alike — must conserve requests, and the whole
    // grid must be byte-identical across engine thread counts.
    let mut spec = ScenarioSpec::from_preset("fleet").unwrap();
    spec.base.network.num_users = 16;
    spec.base.optimizer.max_iters = 25;
    spec.base.workload.episode_s = 0.5;
    spec.base.workload.arrival_rate_hz = 15.0;
    // ≥ 2 distinct AP profiles resolve on the base config
    let aps = spec.base.ap_profiles().unwrap();
    assert!(aps.iter().any(|p| p.name != aps[0].name), "heterogeneous");
    let records = Engine::new(2).run(&spec).unwrap();
    assert_eq!(records.len(), spec.num_cells());
    let csv = to_csv(&records);
    assert_eq!(csv.lines().next().unwrap(), RunRecord::csv_header_dynamic());
    assert!(csv.contains("episode.sharded=false"), "monolithic cells ran");
    assert!(csv.contains("episode.sharded=true"), "sharded cells ran");
    for r in &records {
        let ep = r.episode.as_ref().expect("episode");
        let dy = r.dynamics.as_ref().expect("dynamics");
        assert_eq!(dy.epochs.len(), 4, "0.5 s episode / 0.125 s epochs");
        let requests: usize = dy.epochs.iter().map(|e| e.requests).sum();
        let accounted: usize = dy.epochs.iter().map(|e| e.completed + e.dropped).sum();
        assert_eq!(requests, accounted, "cell {}: epoch conservation", r.cell);
        assert_eq!(requests, ep.n + ep.dropped, "cell {}: total conservation", r.cell);
    }
    let again = Engine::new(1).run(&spec).unwrap();
    assert_eq!(csv, to_csv(&again), "thread invariance");
}

#[test]
fn churn_incremental_preset_runs_end_to_end() {
    // CI-sized `era run --scenario churn-incremental`: the dirty-cohort
    // planner survives real churn (arrivals, departures, handoffs), keeps
    // request conservation, reuses cohorts in steady state, and stays
    // deterministic across engine thread counts.
    let mut spec = ScenarioSpec::from_preset("churn-incremental").unwrap();
    spec.base.network.num_users = 16;
    spec.base.optimizer.max_iters = 25;
    spec.base.workload.episode_s = 0.5;
    spec.base.workload.arrival_rate_hz = 15.0;
    spec.replan_interval_s = Some(0.125);
    spec.strategies = vec!["era".into(), "neurosurgeon".into()];
    spec.axes.clear();
    let records = Engine::new(2).run(&spec).unwrap();
    assert_eq!(records.len(), 2);
    let csv = to_csv(&records);
    assert!(csv.lines().next().unwrap().contains("dyn_cache_hit_frac"));
    for r in &records {
        let ep = r.episode.as_ref().expect("episode");
        let dy = r.dynamics.as_ref().expect("dynamics");
        let requests: usize = dy.epochs.iter().map(|e| e.requests).sum();
        let accounted: usize = dy.epochs.iter().map(|e| e.completed + e.dropped).sum();
        assert_eq!(requests, accounted, "{}: epoch conservation", r.strategy);
        assert_eq!(requests, ep.n + ep.dropped, "{}: total conservation", r.strategy);
        for e in &dy.epochs {
            assert_eq!(
                e.cohorts_reused + e.cohorts_resolved,
                if r.strategy == "era" { e.cohorts } else { 0 },
                "{} epoch {}: reuse accounting",
                r.strategy,
                e.epoch
            );
        }
    }
    let again = Engine::new(1).run(&spec).unwrap();
    assert_eq!(csv, to_csv(&again), "thread invariance");
}
