"""L2 model correctness: split consistency, shape contract, Li-GD utility
semantics and the GD chunk."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params()


@pytest.fixture(scope="module")
def x_input():
    return jnp.linspace(0.0, 1.0, model.ACT_SIZES[0]).reshape(1, -1)


class TestSplitCnn:
    def test_act_sizes_consistent_with_shapes(self):
        for size, shape in zip(model.ACT_SIZES, model.ACT_SHAPES):
            assert int(np.prod(shape)) == size

    @pytest.mark.parametrize("split", range(0, model.NUM_LAYERS + 1))
    def test_split_composition_equals_full(self, params, x_input, split):
        """device_half(s) ∘ edge_half(s) == full model, for every s —
        the property the serving path relies on."""
        full = model.full_model(params, x_input)[0]
        act = model.device_half(params, split, x_input)[0]
        assert act.shape == (1, model.ACT_SIZES[split])
        out = model.edge_half(params, split, act)[0]
        np.testing.assert_allclose(out, full, rtol=1e-4, atol=1e-4)

    def test_deterministic_params(self):
        a = model.init_params()
        b = model.init_params()
        for wa, wb in zip(a, b):
            np.testing.assert_array_equal(wa, wb)

    def test_logits_are_finite_and_distinct(self, params, x_input):
        logits = model.full_model(params, x_input)[0]
        assert logits.shape == (1, 10)
        assert bool(jnp.isfinite(logits).all())
        assert float(jnp.std(logits)) > 1e-4


def _cohort(u=4, m=3, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return model.Cohort(
        g_up=jax.random.uniform(ks[0], (u, m), minval=1e-12, maxval=1e-10),
        g_down=jax.random.uniform(ks[1], (u, m), minval=1e-12, maxval=1e-10),
        bg_up=jnp.full((m,), 1e-15),
        bg_down=jnp.full((u, m), 1e-15),
        f_dev=jnp.linspace(1e8, 3e8, u),
        f_edge=jnp.linspace(4e8, 2e8, u),
        w_bits=jnp.linspace(2e4, 8e4, u),
        q_s=jnp.full((u,), 15e-3),
        c_dev=jnp.linspace(1.5e10, 3e10, u),
        link=jnp.array([1.25e6, 4e-15]),
    )


def _x0(u, m):
    return jnp.concatenate(
        [
            jnp.full((2 * u * m,), 1.0 / m),
            jnp.full((u,), 0.1),
            jnp.full((u,), 1.0),
            jnp.full((u,), 8.0),
        ]
    )


class TestLigd:
    def test_utility_finite_positive(self):
        c = _cohort()
        gamma, (t, e) = model.utility(c, _x0(4, 3))
        assert np.isfinite(float(gamma)) and float(gamma) > 0
        assert bool((t > 0).all()) and bool((e > 0).all())

    def test_device_only_user_ignores_radio(self):
        """f_edge=0 and w_bits=0 ⇒ utility independent of power."""
        c = _cohort()
        c = c._replace(f_edge=jnp.zeros_like(c.f_edge), w_bits=jnp.zeros_like(c.w_bits))
        x = _x0(4, 3)
        g1, _ = model.utility(c, x)
        x2 = x.at[2 * 4 * 3 : 2 * 4 * 3 + 4].set(0.3)  # change p_up
        g2, _ = model.utility(c, x2)
        np.testing.assert_allclose(float(g1), float(g2), rtol=1e-7)

    def test_chunk_descends_and_stays_feasible(self):
        c = _cohort(seed=3)
        x0 = _x0(4, 3)
        g0, _ = model.utility(c, x0)
        xf, gf = model.ligd_chunk(*c[:-1], x0, c.link)
        assert float(gf[0]) <= float(g0) + 1e-6
        b_up = np.asarray(xf[: 4 * 3]).reshape(4, 3)
        np.testing.assert_allclose(b_up.sum(1), 1.0, atol=1e-5)
        assert (b_up >= -1e-6).all()
        r = np.asarray(xf[-4:])
        assert (r >= model.CONSTS["r_min"] - 1e-6).all()
        assert (r <= model.CONSTS["r_max"] + 1e-6).all()

    def test_project_simplex_rows(self):
        v = jnp.array([[0.5, 0.5, 0.5], [-1.0, 2.0, 0.3], [10.0, 0.0, 0.0]])
        p = model._project_simplex(v)
        np.testing.assert_allclose(np.asarray(p).sum(1), 1.0, atol=1e-6)
        assert (np.asarray(p) >= -1e-9).all()

    def test_more_interference_lowers_rate_raises_utility(self):
        c = _cohort(seed=5)
        x = _x0(4, 3)
        g1, _ = model.utility(c, x)
        c2 = c._replace(bg_up=c.bg_up * 1e4)
        g2, _ = model.utility(c2, x)
        assert float(g2) > float(g1)
