"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle, with
hypothesis sweeping shapes and value ranges (the CORE correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul
from compile.kernels.noma import noma_rates
from compile.kernels.ref import matmul_ref, noma_rates_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------------


class TestMatmul:
    @given(
        m=st.integers(1, 80),
        k=st.integers(1, 80),
        n=st.integers(1, 80),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_any_shape(self, m, k, n, seed):
        x = rand(seed, (m, k))
        y = rand(seed + 1, (k, n))
        np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    @given(
        bm=st.sampled_from([8, 16, 32, 128]),
        bk=st.sampled_from([8, 16, 128]),
        bn=st.sampled_from([8, 64, 128]),
    )
    def test_block_shape_invariance(self, bm, bk, bn):
        """The BlockSpec tiling must not change the numerics."""
        x = rand(3, (50, 70))
        y = rand(4, (70, 30))
        np.testing.assert_allclose(
            matmul(x, y, bm=bm, bn=bn, bk=bk), matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_conv_sized_problem(self):
        # the largest matmul the split CNN issues: 1024 patches × 75 × 32
        x = rand(5, (1024, 75))
        y = rand(6, (75, 32))
        np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-4)

    def test_identity(self):
        x = rand(7, (16, 16))
        np.testing.assert_allclose(matmul(x, jnp.eye(16)), x, rtol=1e-6, atol=1e-6)

    def test_zero(self):
        x = rand(8, (9, 11))
        out = matmul(x, jnp.zeros((11, 5)))
        assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# NOMA rate kernel
# ---------------------------------------------------------------------------


class TestNomaRates:
    @given(
        u=st.integers(1, 16),
        m=st.integers(1, 16),
        seed=st.integers(0, 2**16),
        bw=st.sampled_from([1.0, 4e4, 1.25e6]),
    )
    def test_matches_ref(self, u, m, seed, bw):
        beta = rand(seed, (u, m), 0.0, 1.0)
        pg = rand(seed + 1, (u, m), 1e-14, 1e-10)
        d = rand(seed + 2, (u, m), 1e-15, 1e-12)
        got = noma_rates(beta, pg, d, bw=bw)
        want = noma_rates_ref(beta, pg, d, bw=bw)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_zero_beta_zero_rate(self):
        pg = rand(1, (4, 4), 1e-12, 1e-10)
        d = rand(2, (4, 4), 1e-14, 1e-12)
        out = noma_rates(jnp.zeros((4, 4)), pg, d, bw=1e5)
        assert float(jnp.abs(out).max()) == 0.0

    def test_monotone_in_signal(self):
        beta = jnp.ones((2, 2))
        d = jnp.full((2, 2), 1e-13)
        r1 = noma_rates(beta, jnp.full((2, 2), 1e-12), d, bw=1e5)
        r2 = noma_rates(beta, jnp.full((2, 2), 1e-11), d, bw=1e5)
        assert bool((r2 > r1).all())

    def test_gradient_matches_ref_gradient(self):
        """The custom VJP must equal jax.grad of the jnp oracle."""
        beta = rand(11, (3, 3), 0.1, 1.0)
        pg = rand(12, (3, 3), 1e-12, 1e-10)
        d = rand(13, (3, 3), 1e-14, 1e-12)
        bw = 4e4

        def f_kernel(args):
            return noma_rates(*args, bw=bw).sum()

        def f_ref(args):
            return noma_rates_ref(*args, bw=bw).sum()

        g_kernel = jax.grad(f_kernel)((beta, pg, d))
        g_ref = jax.grad(f_ref)((beta, pg, d))
        for a, b in zip(g_kernel, g_ref):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
