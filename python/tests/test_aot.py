"""AOT path: HLO text emission sanity (no elided constants, parseable
shapes, manifest completeness, idempotence)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_to_hlo_text_no_elided_constants():
    params = model.init_params()

    def f(x):
        return model.device_half(params, 1, x)

    low = jax.jit(f).lower(jax.ShapeDtypeStruct((1, model.ACT_SIZES[0]), jnp.float32))
    text = aot.to_hlo_text(low)
    assert "HloModule" in text
    # the silent-zeros failure mode: elided large constants
    assert "{...}" not in text
    # weights actually embedded (conv1 has 5·5·3·32 = 2400 floats)
    assert "f32[5,5,3,32]" in text or "f32[2400" in text or "f32[75,32]" in text


def test_cohort_specs_match_vars_layout():
    u, m = model.COHORT_USERS, model.COHORT_CHANNELS
    specs = aot._cohort_specs(u, m)
    # x vector dimension = U(2M+3)
    assert specs[9].shape == (u * (2 * m + 3),)
    assert specs[0].shape == (u, m)
    assert specs[10].shape == (2,)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")),
    reason="artifacts not built",
)
def test_manifest_lists_all_files():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(art, "manifest.txt")) as f:
        lines = f.read().splitlines()
    files = [l.split()[1] for l in lines if l.startswith("file ")]
    consts = {l.split()[1] for l in lines if l.startswith("const ")}
    # 9 device halves + 9 edge halves + 2 solver + golden.json/.txt
    assert len([f for f in files if f.startswith("split_cnn_dev")]) == model.NUM_LAYERS
    assert len([f for f in files if f.startswith("split_cnn_edge")]) == model.NUM_LAYERS
    assert any(f.startswith("ligd_chunk") for f in files)
    assert any(f.startswith("utility_eval") for f in files)
    for f in files:
        assert os.path.exists(os.path.join(art, f)), f
    for key in ("p_max", "sigmoid_a", "w_t", "gd_step", "cohort_users"):
        assert key in consts


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")),
    reason="artifacts not built",
)
def test_aot_is_idempotent():
    """Re-running without --force must be a fast no-op."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", "../artifacts"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        env=env,
        timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert "up to date" in out.stdout
