import os
import sys

# Make the build-path package importable when pytest runs from the repo root
# (the documented `pytest python/tests/` invocation).
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
