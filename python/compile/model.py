"""L2 JAX models (build-time only; never imported at serving time).

Two compute graphs are defined here and AOT-lowered by `aot.py`:

1. `SplitCnn` — a 9-layer NiN-style CIFAR CNN whose convolutions run on the
   L1 Pallas matmul kernel (im2col → MXU-shaped matmul). For every split
   point s the device half (layers 1..s) and edge half (layers s+1..9) are
   lowered to separate HLO artifacts; the Rust serving loop executes them
   via PJRT. The shape contract mirrors
   `rust/src/runtime/executor.rs::split_cnn_shape`.

2. `ligd` — the relaxed per-cohort utility Γ of the paper (eq.26/27),
   numerically identical to the Rust analytic implementation
   (`rust/src/optimizer/utility.rs`), plus a `lax.fori_loop` chunk of T
   projected-GD steps on jax.grad(Γ). Rate assembly calls the L1 Pallas
   NOMA kernel so the whole chunk lowers to one HLO.

Hyper-constants are baked at lowering time from `CONSTS`, which MUST match
`era::config::Config::default()` — the Rust integration test checks the
manifest against its own defaults.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul
from .kernels.noma import noma_rates

# ---------------------------------------------------------------------------
# Constants mirrored from rust/src/config/mod.rs (Config::default()).
# ---------------------------------------------------------------------------


def _dbm_to_watt(dbm):
    return 10.0 ** ((dbm - 30.0) / 10.0)


CONSTS = dict(
    p_min=_dbm_to_watt(0.0),
    p_max=_dbm_to_watt(25.0),
    p_down_max_factor=20.0,
    r_min=1.0,
    r_max=16.0,
    lambda_gamma=0.85,
    edge_unit_flops=50e9,
    xi_device=1.5e-22,
    xi_edge=8e-24,
    sigmoid_a=50.0,
    w_t=0.4,
    w_r=0.3,
    w_q=0.3,
    delay_scale=50.0,
    energy_scale=10.0,
    resource_scale=0.02,
    result_bits=320.0,
    gd_step=0.005,
    gd_chunk_iters=64,
)

COHORT_USERS = 8
COHORT_CHANNELS = 8


# ---------------------------------------------------------------------------
# 1. SplitCnn
# ---------------------------------------------------------------------------

NUM_LAYERS = 9
# Flat activation sizes at each split point (s=0 is the input) — must match
# rust/src/runtime/executor.rs::split_cnn_shape().
ACT_SIZES = [
    32 * 32 * 3,
    32 * 32 * 32,
    32 * 32 * 16,
    16 * 16 * 16,
    16 * 16 * 32,
    16 * 16 * 16,
    8 * 8 * 16,
    8 * 8 * 32,
    8 * 8 * 10,
    10,
]
ACT_SHAPES = [
    (1, 32, 32, 3),
    (1, 32, 32, 32),
    (1, 32, 32, 16),
    (1, 16, 16, 16),
    (1, 16, 16, 32),
    (1, 16, 16, 16),
    (1, 8, 8, 16),
    (1, 8, 8, 32),
    (1, 8, 8, 10),
    (1, 10),
]


class CnnParams(NamedTuple):
    conv1: jnp.ndarray  # (5,5,3,32)
    mlp1: jnp.ndarray  # (1,1,32,16)
    conv2: jnp.ndarray  # (3,3,16,32)
    mlp2: jnp.ndarray  # (1,1,32,16)
    conv3: jnp.ndarray  # (3,3,16,32)
    mlp3: jnp.ndarray  # (1,1,32,10)


def init_params(seed: int = 42) -> CnnParams:
    """Deterministic He-initialized weights (the 'trained' model stand-in;
    classification accuracy is not under test — serving composition is)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)

    def he(k, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    return CnnParams(
        conv1=he(ks[0], (5, 5, 3, 32)),
        mlp1=he(ks[1], (1, 1, 32, 16)),
        conv2=he(ks[2], (3, 3, 16, 32)),
        mlp2=he(ks[3], (1, 1, 32, 16)),
        conv3=he(ks[4], (3, 3, 16, 32)),
        mlp3=he(ks[5], (1, 1, 32, 10)),
    )


def conv2d_pallas(x, w):
    """SAME stride-1 conv as im2col + the Pallas matmul kernel."""
    n, h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert n == 1 and c == c2
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, i : i + h, j : j + wd, :])
    patches = jnp.concatenate(cols, axis=-1).reshape(h * wd, kh * kw * c)
    out = matmul(patches, w.reshape(kh * kw * c, o))
    return out.reshape(1, h, wd, o)


def _maxpool2(x):
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def _layer(params: CnnParams, idx: int, x):
    """Apply layer `idx` (1-based, matching the split-point convention)."""
    if idx == 1:
        return jax.nn.relu(conv2d_pallas(x, params.conv1))
    if idx == 2:
        return jax.nn.relu(conv2d_pallas(x, params.mlp1))
    if idx == 3:
        return _maxpool2(x)
    if idx == 4:
        return jax.nn.relu(conv2d_pallas(x, params.conv2))
    if idx == 5:
        return jax.nn.relu(conv2d_pallas(x, params.mlp2))
    if idx == 6:
        return _maxpool2(x)
    if idx == 7:
        return jax.nn.relu(conv2d_pallas(x, params.conv3))
    if idx == 8:
        return conv2d_pallas(x, params.mlp3)
    if idx == 9:
        return x.mean(axis=(1, 2))  # global average pool → logits
    raise ValueError(idx)


def device_half(params: CnnParams, split: int, x_flat):
    """Layers 1..split on the (1, ACT_SIZES[0]) flat input."""
    x = x_flat.reshape(ACT_SHAPES[0])
    for idx in range(1, split + 1):
        x = _layer(params, idx, x)
    return (x.reshape(1, ACT_SIZES[split]),)


def edge_half(params: CnnParams, split: int, a_flat):
    """Layers split+1..9 on the flat cut activation."""
    x = a_flat.reshape(ACT_SHAPES[split])
    for idx in range(split + 1, NUM_LAYERS + 1):
        x = _layer(params, idx, x)
    return (x.reshape(1, ACT_SIZES[NUM_LAYERS]),)


def full_model(params: CnnParams, x_flat):
    return edge_half(params, 0, x_flat)


# ---------------------------------------------------------------------------
# 2. Li-GD utility + GD chunk
# ---------------------------------------------------------------------------


def _project_simplex(v):
    """Row-wise Euclidean projection onto the probability simplex."""
    m = v.shape[-1]
    u = jnp.sort(v, axis=-1)[..., ::-1]
    css = jnp.cumsum(u, axis=-1)
    k = jnp.arange(1, m + 1, dtype=v.dtype)
    cond = u - (css - 1.0) / k > 0.0
    rho = jnp.maximum(jnp.sum(cond, axis=-1), 1)
    theta = (
        jnp.take_along_axis(css, rho[..., None] - 1, axis=-1) - 1.0
    ) / rho[..., None].astype(v.dtype)
    return jnp.maximum(v - theta, 0.0)


class Cohort(NamedTuple):
    g_up: jnp.ndarray  # (U, M)
    g_down: jnp.ndarray  # (U, M)
    bg_up: jnp.ndarray  # (M,)
    bg_down: jnp.ndarray  # (U, M)
    f_dev: jnp.ndarray  # (U,)
    f_edge: jnp.ndarray  # (U,)
    w_bits: jnp.ndarray  # (U,)
    q_s: jnp.ndarray  # (U,)
    c_dev: jnp.ndarray  # (U,)
    link: jnp.ndarray  # (2,) = [bw_hz, noise_w]


def _unpack(x, u, m):
    b_up = x[: u * m].reshape(u, m)
    b_dn = x[u * m : 2 * u * m].reshape(u, m)
    p_up = x[2 * u * m : 2 * u * m + u]
    p_dn = x[2 * u * m + u : 2 * u * m + 2 * u]
    r = x[2 * u * m + 2 * u :]
    return b_up, b_dn, p_up, p_dn, r


def utility(c: Cohort, x):
    """Γ — mirrors rust/src/optimizer/utility.rs::eval exactly."""
    u, m = c.g_up.shape
    bw = c.link[0]
    noise = c.link[1]
    b_up, b_dn, p_up, p_dn, r = _unpack(x, u, m)

    # Uplink: weaker-user interference mask per channel (strict <).
    weaker = (c.g_up[None, :, :] < c.g_up[:, None, :]).astype(x.dtype)
    rec = b_up * p_up[:, None] * c.g_up  # received power per (v, m)
    intra_up = jnp.einsum("ivm,vm->im", weaker, rec)
    d_up = c.bg_up[None, :] + noise + intra_up
    pg_up = p_up[:, None] * c.g_up
    rate_up = (noma_rates(b_up, pg_up, d_up, bw=1.0) * bw).sum(axis=1)

    # Downlink: stronger-user superposition interference (strict >),
    # scaled by the victim's own gain.
    stronger = (c.g_down[None, :, :] > c.g_down[:, None, :]).astype(x.dtype)
    comp = b_dn * p_dn[:, None]  # (v, m)
    intra_dn = jnp.einsum("ivm,vm->im", stronger, comp) * c.g_down
    d_dn = intra_dn + c.bg_down + noise
    pg_dn = p_dn[:, None] * c.g_down
    rate_dn = (noma_rates(b_dn, pg_dn, d_dn, bw=1.0) * bw).sum(axis=1)

    offloads = c.f_edge > 0.0
    lam = jnp.maximum(r, 1e-9) ** CONSTS["lambda_gamma"]
    t_dev = c.f_dev / c.c_dev
    t_srv = jnp.where(offloads, c.f_edge / (lam * CONSTS["edge_unit_flops"]), 0.0)
    t_up = jnp.where(c.w_bits > 0.0, c.w_bits / rate_up, 0.0)
    t_dn = jnp.where(offloads, CONSTS["result_bits"] / rate_dn, 0.0)
    t = t_dev + t_srv + t_up + t_dn

    e_dev = CONSTS["xi_device"] * c.c_dev**2 * c.f_dev / 1e9
    cap = lam * CONSTS["edge_unit_flops"]
    e_srv = jnp.where(offloads, CONSTS["xi_edge"] * cap**2 * c.f_edge / 1e9, 0.0)
    e_up = jnp.where(c.w_bits > 0.0, p_up * c.w_bits / rate_up, 0.0)
    e_dn = jnp.where(offloads, p_dn * CONSTS["result_bits"] / rate_dn, 0.0)
    e = e_dev + e_srv + e_up + e_dn

    xq = t / c.q_s
    rsig = jax.nn.sigmoid(CONSTS["sigmoid_a"] * (xq - 1.0))
    dct = (t - c.q_s) * rsig
    resource = jnp.where(offloads, lam, 0.0)

    util = (
        CONSTS["w_t"] * CONSTS["delay_scale"] * t
        + CONSTS["w_r"]
        * (CONSTS["energy_scale"] * e + CONSTS["resource_scale"] * resource)
        + CONSTS["w_q"] * (CONSTS["delay_scale"] * dct + rsig)
    )
    return util.sum(), (t, e)


def utility_eval(
    g_up, g_down, bg_up, bg_down, f_dev, f_edge, w_bits, q_s, c_dev, x, link
):
    """AOT entry: Γ plus per-user delay/energy (parity test vs Rust)."""
    c = Cohort(g_up, g_down, bg_up, bg_down, f_dev, f_edge, w_bits, q_s, c_dev, link)
    gamma, (t, e) = utility(c, x)
    return gamma.reshape(1), t, e


def _project(x, u, m):
    b_up, b_dn, p_up, p_dn, r = _unpack(x, u, m)
    b_up = _project_simplex(b_up)
    b_dn = _project_simplex(b_dn)
    p_up = jnp.clip(p_up, CONSTS["p_min"], CONSTS["p_max"])
    p_dn = jnp.clip(
        p_dn, CONSTS["p_min"], CONSTS["p_down_max_factor"] * CONSTS["p_max"]
    )
    r = jnp.clip(r, CONSTS["r_min"], CONSTS["r_max"])
    return jnp.concatenate([b_up.ravel(), b_dn.ravel(), p_up, p_dn, r])


def _scales(u, m, dtype):
    """Diagonal preconditioner — mirrors optimizer/ligd.rs::scales."""
    pr = (CONSTS["p_max"] - CONSTS["p_min"]) ** 2
    pdr = (CONSTS["p_down_max_factor"] * CONSTS["p_max"] - CONSTS["p_min"]) ** 2
    rr = (CONSTS["r_max"] - CONSTS["r_min"]) ** 2
    return jnp.concatenate(
        [
            jnp.ones(2 * u * m, dtype),
            jnp.full((u,), pr, dtype),
            jnp.full((u,), pdr, dtype),
            jnp.full((u,), rr, dtype),
        ]
    )


def ligd_chunk(
    g_up, g_down, bg_up, bg_down, f_dev, f_edge, w_bits, q_s, c_dev, x0, link
):
    """T fixed-step projected-GD iterations on Γ (the AOT solver chunk)."""
    u, m = g_up.shape
    c = Cohort(g_up, g_down, bg_up, bg_down, f_dev, f_edge, w_bits, q_s, c_dev, link)
    grad_fn = jax.grad(lambda x: utility(c, x)[0])
    scal = _scales(u, m, x0.dtype)
    step = CONSTS["gd_step"]

    def body(_, x):
        g = grad_fn(x)
        return _project(x - step * scal * g, u, m)

    x_final = jax.lax.fori_loop(0, CONSTS["gd_chunk_iters"], body, _project(x0, u, m))
    gamma, _ = utility(c, x_final)
    return x_final, gamma.reshape(1)
