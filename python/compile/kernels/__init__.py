"""L1 Pallas kernels (build-time only; lowered into the HLO artifacts)."""

from . import matmul, noma, ref  # noqa: F401
