"""L1 Pallas kernel: batched NOMA rate evaluation — the inner loop of every
Li-GD utility/gradient step.

Given the per-(user, channel) SINR numerator/denominator pieces, computes
    rate[u, m] = beta[u, m] * bw * log2(1 + p[u] * g[u, m] / d[u, m])
for a whole solver cohort at once. The (U, M) block is VMEM-resident
(U=8 × M=8 f32 ≈ 256 B per operand, vastly under the ~16 MiB VMEM budget;
the lane dimension M is padded to the 128-lane VPU register shape on a real
TPU). Interference denominators `d` carry the SIC prefix sums computed by
the caller (they need a sort, which stays in jnp).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rate_kernel(beta_ref, pg_ref, d_ref, o_ref, *, bw):
    s = pg_ref[...] / d_ref[...]
    o_ref[...] = beta_ref[...] * bw * (jnp.log1p(s) / jnp.log(2.0))


def _noma_rates_fwd_impl(beta, pg, d, *, bw):
    u, m = beta.shape
    kernel = functools.partial(_rate_kernel, bw=bw)
    return pl.pallas_call(
        kernel,
        # One VMEM block — the cohort is tiny by construction.
        in_specs=[pl.BlockSpec((u, m), lambda: (0, 0))] * 3,
        out_specs=pl.BlockSpec((u, m), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((u, m), jnp.float32),
        grid=(),
        interpret=True,
    )(beta.astype(jnp.float32), pg.astype(jnp.float32), d.astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _noma_rates(beta, pg, d, bw):
    return _noma_rates_fwd_impl(beta, pg, d, bw=bw)


def _noma_fwd(beta, pg, d, bw):
    return _noma_rates_fwd_impl(beta, pg, d, bw=bw), (beta, pg, d)


def _noma_bwd(bw, res, ct):
    """Analytic VJP of rate = β·bw·log2(1 + pg/d) — pallas_call has no
    built-in reverse rule, so the backward pass is the closed form (the
    same partials the Rust gradient uses, eq.28-35's log-derivative)."""
    beta, pg, d = res
    s = pg / d
    ln2 = jnp.log(2.0)
    log_term = jnp.log1p(s) / ln2
    d_beta = ct * bw * log_term
    common = ct * beta * bw / ((1.0 + s) * ln2)
    d_pg = common / d
    d_d = -common * s / d
    return d_beta, d_pg, d_d


_noma_rates.defvjp(_noma_fwd, _noma_bwd)


def noma_rates(beta, pg, d, *, bw):
    """Per-(user, channel) NOMA rate contributions.

    Args:
      beta: (U, M) relaxed subchannel shares.
      pg:   (U, M) received signal power p_u * |h_{u,m}|^2.
      d:    (U, M) SINR denominators (interference + noise).
      bw:   per-subchannel bandwidth (Hz), static.

    Returns (U, M) rate contributions; sum over M gives the user rate.
    Differentiable: forward runs the Pallas kernel, backward is the
    closed-form VJP above.
    """
    return _noma_rates(beta, pg, d, float(bw))
