"""L1 Pallas kernel: tiled matmul — the MXU hot-spot of the split CNN.

Convolutions are lowered to im2col + matmul so the inner product lands on
the MXU systolic array on a real TPU (bfloat16-friendly `jnp.dot` with
`preferred_element_type=f32`); BlockSpec tiles the (patches × filters)
product into `bm × bn × bk` VMEM-resident blocks with accumulation over the
K grid axis (the HBM↔VMEM schedule a CUDA kernel would express with
threadblocks).

Pallas runs under `interpret=True` here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that both the
pytest oracle checks and the Rust PJRT runtime can run (see DESIGN.md
§Hardware-Adaptation; real-TPU efficiency is estimated there, not measured).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=128, bn=128, bk=128):
    """`x @ y` via the Pallas tiled kernel (f32), any shapes."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    # Block sizes never exceed the (padded) problem.
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    bk = min(bk, max(8, k))
    xp = _pad_to(x.astype(jnp.float32), bm, bk)
    yp = _pad_to(y.astype(jnp.float32), bk, bn)
    pm, pk = xp.shape[0] // bm, xp.shape[1] // bk
    pn = yp.shape[1] // bn
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(pm, pn, pk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm * bm, pn * bn), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]
