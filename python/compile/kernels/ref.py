"""Pure-jnp correctness oracles for the Pallas kernels (pytest compares
kernel outputs against these; hypothesis sweeps shapes and values)."""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Oracle for kernels.matmul.matmul."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def noma_rates_ref(beta, pg, d, *, bw):
    """Oracle for kernels.noma.noma_rates."""
    s = pg.astype(jnp.float32) / d.astype(jnp.float32)
    return beta.astype(jnp.float32) * bw * jnp.log2(1.0 + s)
