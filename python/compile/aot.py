"""AOT lowering: jax → HLO **text** artifacts for the Rust PJRT runtime.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Outputs (artifacts/):
  split_cnn_dev_s{1..9}.hlo.txt    device half of the split CNN
  split_cnn_edge_s{0..8}.hlo.txt   edge half
  ligd_chunk_c8_m8.hlo.txt         64 projected-GD steps for one cohort
  utility_eval_c8_m8.hlo.txt       Γ + per-user (T, E) — Rust parity test
  golden.json                      golden logits + cohort parity fixture
  manifest.txt                     file list + baked hyper-constants

Idempotent: `make artifacts` skips lowering when the manifest is newer than
every input under python/compile/.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals as
    # `constant({...})`, which the HLO text parser silently reads as zeros —
    # the CNN weights would vanish.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_split_cnn(outdir, params, files):
    for s in range(0, model.NUM_LAYERS + 1):
        if s >= 1:
            fn = functools.partial(model.device_half, params, s)
            low = jax.jit(fn).lower(_spec((1, model.ACT_SIZES[0])))
            name = f"split_cnn_dev_s{s}.hlo.txt"
            with open(os.path.join(outdir, name), "w") as f:
                f.write(to_hlo_text(low))
            files.append(name)
        if s < model.NUM_LAYERS:
            fn = functools.partial(model.edge_half, params, s)
            low = jax.jit(fn).lower(_spec((1, model.ACT_SIZES[s])))
            name = f"split_cnn_edge_s{s}.hlo.txt"
            with open(os.path.join(outdir, name), "w") as f:
                f.write(to_hlo_text(low))
            files.append(name)


def _cohort_specs(u, m):
    d = u * (2 * m + 3)
    return [
        _spec((u, m)),  # g_up
        _spec((u, m)),  # g_down
        _spec((m,)),  # bg_up
        _spec((u, m)),  # bg_down
        _spec((u,)),  # f_dev
        _spec((u,)),  # f_edge
        _spec((u,)),  # w_bits
        _spec((u,)),  # q_s
        _spec((u,)),  # c_dev
        _spec((d,)),  # x
        _spec((2,)),  # link = [bw, noise]
    ]


def lower_ligd(outdir, files):
    u, m = model.COHORT_USERS, model.COHORT_CHANNELS
    specs = _cohort_specs(u, m)
    low = jax.jit(model.ligd_chunk).lower(*specs)
    name = f"ligd_chunk_c{u}_m{m}.hlo.txt"
    with open(os.path.join(outdir, name), "w") as f:
        f.write(to_hlo_text(low))
    files.append(name)
    low = jax.jit(model.utility_eval).lower(*specs)
    name = f"utility_eval_c{u}_m{m}.hlo.txt"
    with open(os.path.join(outdir, name), "w") as f:
        f.write(to_hlo_text(low))
    files.append(name)


def golden_fixture(params):
    """Golden outputs for the Rust integration tests."""
    x = jnp.linspace(0.0, 1.0, model.ACT_SIZES[0], dtype=jnp.float32).reshape(1, -1)
    logits = model.full_model(params, x)[0]
    # Deterministic cohort parity fixture.
    u, m = model.COHORT_USERS, model.COHORT_CHANNELS
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 4)
    g_up = jax.random.uniform(ks[0], (u, m), minval=1e-12, maxval=1e-10)
    g_dn = jax.random.uniform(ks[1], (u, m), minval=1e-12, maxval=1e-10)
    bg_up = jnp.full((m,), 1e-15)
    bg_dn = jnp.full((u, m), 1e-15)
    f_dev = jnp.linspace(1e8, 3e8, u)
    f_edge = jnp.linspace(4e8, 2e8, u)
    w_bits = jnp.linspace(2e4, 8e4, u)
    q_s = jnp.full((u,), 15e-3)
    c_dev = jnp.linspace(1.5e10, 3e10, u)
    link = jnp.array([1.25e6, 4e-15])
    x0 = jnp.concatenate(
        [
            jnp.full((2 * u * m,), 1.0 / m),
            jnp.full((u,), 0.1),
            jnp.full((u,), 1.0),
            jnp.full((u,), 8.0),
        ]
    )
    gamma, t, e = model.utility_eval(
        g_up, g_dn, bg_up, bg_dn, f_dev, f_edge, w_bits, q_s, c_dev, x0, link
    )
    _, gamma_after = model.ligd_chunk(
        g_up, g_dn, bg_up, bg_dn, f_dev, f_edge, w_bits, q_s, c_dev, x0, link
    )
    return {
        "input_desc": "linspace(0,1,3072)",
        "logits": [float(v) for v in logits.ravel()],
        "cohort": {
            "g_up": [float(v) for v in g_up.ravel()],
            "g_down": [float(v) for v in g_dn.ravel()],
            "bg_up": [float(v) for v in bg_up.ravel()],
            "bg_down": [float(v) for v in bg_dn.ravel()],
            "f_dev": [float(v) for v in f_dev],
            "f_edge": [float(v) for v in f_edge],
            "w_bits": [float(v) for v in w_bits],
            "q_s": [float(v) for v in q_s],
            "c_dev": [float(v) for v in c_dev],
            "link": [float(v) for v in link],
            "x0": [float(v) for v in x0],
            "gamma": float(gamma[0]),
            "t": [float(v) for v in t],
            "e": [float(v) for v in e],
            "gamma_after_chunk": float(gamma_after[0]),
        },
    }


def inputs_mtime():
    root = os.path.dirname(os.path.abspath(__file__))
    latest = 0.0
    for dirpath, _, names in os.walk(root):
        for n in names:
            if n.endswith(".py"):
                latest = max(latest, os.path.getmtime(os.path.join(dirpath, n)))
    return latest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)
    manifest = os.path.join(outdir, "manifest.txt")
    if (
        not args.force
        and os.path.exists(manifest)
        and os.path.getmtime(manifest) >= inputs_mtime()
    ):
        print(f"artifacts up to date in {outdir}")
        return

    params = model.init_params()
    files = []
    lower_split_cnn(outdir, params, files)
    lower_ligd(outdir, files)
    fixture = golden_fixture(params)
    with open(os.path.join(outdir, "golden.json"), "w") as f:
        json.dump(fixture, f)
    files.append("golden.json")
    # Flat `key v1 v2 ...` form for the Rust tests (no serde offline).
    with open(os.path.join(outdir, "golden.txt"), "w") as f:
        f.write("logits " + " ".join(f"{v!r}" for v in fixture["logits"]) + "\n")
        for k, v in fixture["cohort"].items():
            vals = v if isinstance(v, list) else [v]
            f.write(f"{k} " + " ".join(f"{x!r}" for x in vals) + "\n")
    files.append("golden.txt")

    with open(manifest, "w") as f:
        f.write("# era artifacts — generated by python -m compile.aot\n")
        for name in files:
            f.write(f"file {name}\n")
        for k, v in model.CONSTS.items():
            f.write(f"const {k} {v!r}\n")
        f.write(f"const cohort_users {model.COHORT_USERS}\n")
        f.write(f"const cohort_channels {model.COHORT_CHANNELS}\n")
        f.write(f"const num_layers {model.NUM_LAYERS}\n")
    print(f"wrote {len(files)} artifacts + manifest to {outdir}")


if __name__ == "__main__":
    main()
